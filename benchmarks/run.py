"""Benchmark harness — one function per paper table/figure + kernel
microbenchmarks + the roofline table.  Prints ``name,us_per_call,derived``
CSV rows (derived carries the table-specific payload).

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig3,...]
"""
from __future__ import annotations

import argparse
import sys
import time


def _row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


# ------------------------------------------------- Table I (main result)


def table1_comparison():
    """Paper Table I: accuracy + communication cost, 4 training methods.

    Accuracy at benchmark scale (structurally identical protocol);
    communication in BOTH the benchmark scale and the paper's exact
    constants (N=67, K=2, T=100/350, B=3 — eq. 9 is scale-free)."""
    from benchmarks.fl_common import (PAPER_B, PAPER_K, PAPER_N,
                                      PAPER_T_CEFL, PAPER_T_REG,
                                      bench_harness)
    from repro.core import comm_cost as CC
    from repro.core.fl import (run_cefl, run_fedper, run_individual,
                               run_regular_fl)
    from repro.models.fd_cnn import layer_sizes_bytes

    h = bench_harness()
    delta = list(layer_sizes_bytes().values())
    paper = {
        "regular_fl": CC.regular_fl_cost(delta, PAPER_N, PAPER_T_REG),
        "fedper": CC.fedper_cost(delta, PAPER_N, PAPER_T_REG, PAPER_B),
        "individual": 0,
        "cefl": CC.cefl_cost(delta, PAPER_N, PAPER_K, PAPER_T_CEFL,
                             PAPER_B).total,
    }
    for fn in (run_regular_fl, run_fedper, run_individual, run_cefl):
        t0 = time.time()
        r = fn(h)
        us = (time.time() - t0) * 1e6
        _row(f"table1_{r.name}", us,
             f"acc={r.accuracy:.4f};bench_comm_MB={r.comm_bytes/1e6:.2f};"
             f"paper_comm_MB={paper[r.name]/1e6:.1f};episodes={r.episodes}")
    sav = 1 - paper["cefl"] / paper["regular_fl"]
    _row("table1_savings", 0.0,
         f"paper_constants_savings={100*sav:.2f}%;paper_claim=98.45%")


# --------------------------------------------------- Fig. 3 (K sweep)


def fig3_k_sweep():
    """CEFL accuracy vs number of clusters K (paper: K=2 optimal)."""
    from benchmarks.fl_common import bench_harness
    from repro.core.fl import run_cefl
    h = bench_harness()
    for k in (2, 4, 6):
        t0 = time.time()
        r = run_cefl(h, k=k)
        _row(f"fig3_k{k}", (time.time() - t0) * 1e6,
             f"acc={r.accuracy:.4f};clusters={int(r.extras['labels'].max())+1};"
             f"comm_MB={r.comm_bytes/1e6:.2f}")


# ----------------------------------------------- Fig. 4 (convergence)


def fig4_convergence():
    """Accuracy-vs-episodes traces for the 4 methods."""
    from benchmarks.fl_common import bench_harness
    from repro.core.fl import (run_cefl, run_fedper, run_individual,
                               run_regular_fl)
    h = bench_harness()
    for fn in (run_regular_fl, run_fedper, run_individual, run_cefl):
        t0 = time.time()
        r = fn(h)
        trace = "|".join(f"{e}:{a:.3f}" for e, a in r.history)
        _row(f"fig4_{r.name}", (time.time() - t0) * 1e6, f"trace={trace}")


# ------------------------------------------- Fig. 5 (heterogeneity)


def fig5_heterogeneity():
    """Per-client accuracy for characteristic clients: largest/most
    balanced, smallest, most label-skewed (paper's clients 4/31/50)."""
    import numpy as np
    from benchmarks.fl_common import bench_harness
    from repro.core.fl import run_cefl, run_individual, run_regular_fl
    h = bench_harness()
    sizes = np.array([len(c) for c in h.data.clients])
    skew = np.array([np.bincount(c.y, minlength=8).max() / max(len(c), 1)
                     for c in h.data.clients])
    picks = {"big": int(sizes.argmax()), "small": int(sizes.argmin()),
             "skewed": int(skew.argmax())}
    for fn in (run_regular_fl, run_individual, run_cefl):
        t0 = time.time()
        r = fn(h)
        payload = ";".join(
            f"{tag}(c{idx},n={sizes[idx]})={r.per_client[idx]:.3f}"
            for tag, idx in picks.items())
        _row(f"fig5_{r.name}", (time.time() - t0) * 1e6, payload)


# ------------------------------------------------- kernel microbench


def kernels_microbench():
    """us/call for the Pallas kernels (interpret mode — the correctness
    path on CPU) and their jnp reference ops (XLA-compiled baseline)."""
    import jax
    from repro.kernels import ops, ref
    import jax.numpy as jnp

    key = jax.random.PRNGKey(0)

    def timeit(f, *a, n=3):
        jax.block_until_ready(f(*a))
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(f(*a))
        return (time.time() - t0) / n * 1e6

    w = jax.random.normal(key, (67, 4096))
    us_ref = timeit(jax.jit(ref.pairwise_dist_ref), w)
    us_pal = timeit(lambda x: ops.pairwise_dist(x, bn=32, bp=512), w)
    _row("kernel_pairwise_ref_jit", us_ref, "N=67;P=4096")
    _row("kernel_pairwise_pallas_interpret", us_pal,
         "N=67;P=4096;interpret=True")

    q = jax.random.normal(key, (2, 256, 8, 64))
    k = jax.random.normal(key, (2, 256, 2, 64))
    v = jax.random.normal(key, (2, 256, 2, 64))
    us_f = timeit(lambda *a: ops.flash_attention(*a, causal=True,
                                                 bq=128, bk=128), q, k, v)

    def _ref_fa(q, k, v):
        g = 4
        qr = q.transpose(0, 2, 1, 3).reshape(16, 256, 64)
        kr = jnp.repeat(k.transpose(0, 2, 1, 3), g, 1).reshape(16, 256, 64)
        vr = jnp.repeat(v.transpose(0, 2, 1, 3), g, 1).reshape(16, 256, 64)
        return ref.flash_attention_ref(qr, kr, vr, causal=True)

    us_r = timeit(jax.jit(_ref_fa), q, k, v)
    _row("kernel_flash_ref_jit", us_r, "B=2;S=256;H=8;d=64")
    _row("kernel_flash_pallas_interpret", us_f, "B=2;S=256;H=8;d=64")


# --------------------------------------------------- roofline table


def roofline_table():
    """§Roofline: analytic three-term model for every applicable
    (arch × shape) on the single-pod mesh shape — no compile needed
    (the HLO cross-checks live in experiments/dryrun_*.jsonl)."""
    from repro.configs.registry import (ARCHS, applicable_shapes,
                                        get_config, shape_config)
    from repro.launch import analytic as A

    class _FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (16, 16)

    for arch in ARCHS:
        cfg0 = get_config(arch)
        for shape in applicable_shapes(cfg0):
            cfg = shape_config(cfg0, shape)
            t0 = time.time()
            r = A.analytic_roofline(cfg, shape, _FakeMesh)
            us = (time.time() - t0) * 1e6
            util = r.model_flops / (r.flops_per_dev * 256) \
                if r.flops_per_dev else 0.0
            _row(f"roofline_{arch}_{shape}", us,
                 f"compute_s={r.compute_s:.4e};memory_s={r.memory_s:.4e};"
                 f"collective_s={r.collective_s:.4e};dominant={r.dominant};"
                 f"useful_ratio={util:.3f}")


# ----------------------------------------- related work (paper §II)


def related_baselines():
    """FedPAQ + CMFL (the comm-efficiency baselines the paper cites),
    same harness/data as Table I."""
    from benchmarks.fl_common import bench_harness
    from repro.core.related import run_cmfl, run_fedpaq
    h = bench_harness()
    for fn, kw in ((run_fedpaq, {"participation": 0.5, "bits": 8}),
                   (run_cmfl, {"threshold": 0.45})):
        t0 = time.time()
        r = fn(h, **kw)
        _row(f"related_{r.name}", (time.time() - t0) * 1e6,
             f"acc={r.accuracy:.4f};bench_comm_MB={r.comm_bytes/1e6:.2f}")


# --------------------------------------------- ablation: base layers B


def ablation_base_layers():
    """Beyond-paper ablation: eq. 9's B (base-layer count) trades FL-round
    bytes against how much of the network the leaders share.  The paper
    fixes B implicitly; we sweep it."""
    from benchmarks.fl_common import bench_harness
    from repro.core import comm_cost as CC
    from repro.core.fl import run_cefl
    from repro.models.fd_cnn import layer_sizes_bytes
    delta = list(layer_sizes_bytes().values())
    h = bench_harness()
    for B in (1, 2, 3, 4):
        t0 = time.time()
        h.cfg.base_layers = B
        r = run_cefl(h)
        paper_cost = CC.cefl_cost(delta, 67, 2, 100, B).total
        _row(f"ablation_B{B}", (time.time() - t0) * 1e6,
             f"acc={r.accuracy:.4f};bench_comm_MB={r.comm_bytes/1e6:.2f};"
             f"paper_comm_MB={paper_cost/1e6:.1f}")
    h.cfg.base_layers = 2


# ------------------------------------------------------ comm scaling


def comm_scaling():
    """Eq. 9 cost vs N — the scaling the paper's §IV-C derives (CEFL
    grows with N only via the one-shot clustering/transfer terms)."""
    from repro.core import comm_cost as CC
    from repro.models.fd_cnn import layer_sizes_bytes
    delta = list(layer_sizes_bytes().values())
    for n in (16, 67, 256):
        t0 = time.time()
        cefl = CC.cefl_cost(delta, n, 2, 100, 3).total
        reg = CC.regular_fl_cost(delta, n, 350)
        _row(f"comm_scaling_N{n}", (time.time() - t0) * 1e6,
             f"cefl_MB={cefl/1e6:.1f};regular_MB={reg/1e6:.1f};"
             f"savings={100*(1-cefl/reg):.2f}%")


ALL = {
    "table1": table1_comparison,
    "fig3": fig3_k_sweep,
    "fig4": fig4_convergence,
    "fig5": fig5_heterogeneity,
    "kernels": kernels_microbench,
    "roofline": roofline_table,
    "related": related_baselines,
    "ablation": ablation_base_layers,
    "comm": comm_scaling,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(ALL))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(ALL)
    print("name,us_per_call,derived")
    for n in names:
        ALL[n]()


if __name__ == "__main__":
    main()
