"""Shared scaled-down FL benchmark configuration.

The paper's full protocol (67 clients × 350 rounds × 8 episodes) takes
GPU-days; benchmarks run a structurally identical, scaled-down protocol
(the comm-cost FORMULAS are evaluated at both the benchmark scale and
the paper's constants — eq. 9 is exact at any scale)."""
from __future__ import annotations

import functools

from repro.core.fl import FLConfig, FLHarness

BENCH_FL = FLConfig(
    n_clients=16, k_clusters=2, t_rounds=10, local_episodes=2,
    transfer_episodes=16, warmup_episodes=1, steps_per_episode=2,
    data_scale=0.35, eval_every=2, seed=1, heterogeneity=0.6)

# paper constants for the exact eq. 9 accounting
PAPER_N, PAPER_K, PAPER_T_CEFL, PAPER_T_REG, PAPER_B = 67, 2, 100, 350, 3


@functools.lru_cache(maxsize=1)
def bench_harness() -> FLHarness:
    return FLHarness(BENCH_FL)
