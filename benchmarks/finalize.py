"""Assemble the final EXPERIMENTS.md §Dry-run + §Roofline tables.

Merges the dry-run JSONL files (latest record wins per combo),
recomputes the analytic roofline terms with the current cost model
(earlier records carry pre-fix decode terms), and prints markdown.

    PYTHONPATH=src python -m benchmarks.finalize
"""
from __future__ import annotations

import glob
import json
import math
import sys

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import get_config, shape_config
from repro.launch import analytic as A

SINGLE_FILES = ["experiments/dryrun_single.jsonl",
                "experiments/dryrun_refresh.jsonl"]
MULTI_FILES = ["experiments/dryrun_multi.jsonl",
               "experiments/dryrun_multi2.jsonl"]
CEFL_FILES = ["experiments/dryrun_cefl.jsonl",
              "experiments/dryrun_cefl2.jsonl"]


class _Mesh:
    def __init__(self, shape_str):
        dims = [int(x) for x in shape_str.split("x")]
        if len(dims) == 3:
            self.axis_names = ("pod", "data", "model")
        else:
            self.axis_names = ("data", "model")

        class D:
            shape = tuple(dims)
        self.devices = D


def load_latest(paths):
    recs = {}
    for p in paths:
        try:
            with open(p) as f:
                for line in f:
                    r = json.loads(line)
                    if r.get("overrides"):
                        continue            # lever runs live in §Perf
                    recs[(r["arch"], r["shape"], r["mesh"], r["mode"])] = r
        except FileNotFoundError:
            pass
    return list(recs.values())


def recompute_roofline(r):
    cfg = shape_config(get_config(r["arch"]), r["shape"])
    mesh = _Mesh(r["mesh"])
    ar = A.analytic_roofline(cfg, r["shape"], mesh,
                             mode=("cefl" if r["mode"] == "cefl" else "ddp"),
                             inner_steps=8)
    n = math.prod(mesh.devices.shape)
    r["roofline"] = {
        "compute_s": ar.compute_s, "memory_s": ar.memory_s,
        "collective_s": ar.collective_s, "dominant": ar.dominant,
        "flops_per_dev": ar.flops_per_dev, "hbm_per_dev": ar.hbm_per_dev,
        "ici_per_dev": ar.ici_per_dev, "dcn_per_dev": ar.dcn_per_dev,
        "model_flops": ar.model_flops,
        "useful_ratio": (ar.model_flops / (ar.flops_per_dev * n)
                         if ar.flops_per_dev else None),
    }
    return r


ORDER = ["hubert-xlarge", "qwen3-moe-235b-a22b", "yi-6b",
         "granite-moe-3b-a800m", "xlstm-350m", "nemotron-4-340b",
         "codeqwen1.5-7b", "qwen2.5-32b", "zamba2-1.2b",
         "phi-3-vision-4.2b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _key(r):
    return (ORDER.index(r["arch"]), SHAPES.index(r["shape"]))


def roofline_md(recs):
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | MODEL/HLO | what would move the dominant term |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=_key):
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} | "
            f"{rf['memory_s']:.3e} | {rf['collective_s']:.3e} | "
            f"**{rf['dominant']}** | {rf['useful_ratio']:.2f} | "
            f"{_next_move(r)} |")
    return "\n".join(rows)


def _next_move(r):
    cfg = get_config(r["arch"])
    dom = r["roofline"]["dominant"]
    if dom == "collective":
        if cfg.arch_type == "moe":
            return "fp8 a2a dispatch; locality-aware expert placement"
        if r["shape"] == "train_4k":
            return "CEFL partial sync across pods (ε local steps)"
        return "larger per-device batch (amortize TP all-reduce)"
    if dom == "memory":
        return "int8 KV cache (+scales); fuse cache read into attention"
    return "bf16-native matmuls already; raise tokens/chip (less remat)"


def dryrun_md(recs):
    rows = ["| arch | shape | mesh | mode | temp GB/dev | args GB/dev | "
            "fits 16GB | top collectives (link GB once-through) |",
            "|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda x: (_key(x), x["mesh"], x["mode"])):
        mem = r["memory"]
        tot = (mem["temp_bytes"] + mem["argument_bytes"]) / 1e9
        sched = sorted(r["collective_schedule"],
                       key=lambda s: -s["link_bytes"])[:3]
        s = "; ".join(f"{x['kind']}×{x['count']}(g{x['group']}"
                      f"{',DCN' if x['dcn'] else ''})"
                      f"={x['link_bytes']/1e9:.2f}" for x in sched)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['mode']} | "
            f"{mem['temp_bytes']/1e9:.2f} | {mem['argument_bytes']/1e9:.2f} | "
            f"{'yes' if tot <= 16 else 'NO'} | {s or '—'} |")
    return "\n".join(rows)


def main():
    single = [recompute_roofline(r) for r in load_latest(SINGLE_FILES)]
    multi = [recompute_roofline(r) for r in load_latest(MULTI_FILES)]
    cefl = [recompute_roofline(r) for r in load_latest(CEFL_FILES)]
    with open("experiments/final_single.jsonl", "w") as f:
        for r in single:
            f.write(json.dumps(r) + "\n")
    out = []
    out.append("<!-- generated by benchmarks/finalize.py -->\n")
    out.append("### Roofline table — single pod (16×16 = 256 chips)\n")
    out.append(roofline_md(single))
    out.append("\n\n### Dry-run memory + collective schedule — single pod\n")
    out.append(dryrun_md(single))
    out.append("\n\n### Dry-run — multi-pod (2×16×16 = 512 chips, DDP)\n")
    out.append(dryrun_md(multi))
    out.append("\n\n### Dry-run — multi-pod CEFL rounds (the paper's "
               "protocol; ε=2 inner steps per round)\n")
    out.append(dryrun_md(cefl))
    text = "\n".join(out)
    with open("experiments/tables.md", "w") as f:
        f.write(text)
    print(text[:2000])
    print(f"\n[finalize] {len(single)} single, {len(multi)} multi, "
          f"{len(cefl)} cefl records -> experiments/tables.md")


if __name__ == "__main__":
    main()
