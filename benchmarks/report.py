"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSONL records.

    PYTHONPATH=src python -m benchmarks.report experiments/dryrun_single.jsonl
"""
from __future__ import annotations

import json
import sys


def load(path):
    out = []
    seen = set()
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            key = (r["arch"], r["shape"], r["mesh"], r["mode"])
            if key in seen:
                continue
            seen.add(key)
            out.append(r)
    return out


def dryrun_table(recs) -> str:
    hdr = ("| arch | shape | mesh | mode | temp GB/dev | args GB/dev | "
           "collective schedule (kind×count, link GB once-through) |\n"
           "|---|---|---|---|---|---|---|\n")
    rows = []
    for r in recs:
        mem = r["memory"]
        sched = "; ".join(
            f"{s['kind']}×{s['count']}(g{s['group']}"
            f"{',DCN' if s['dcn'] else ''})={s['link_bytes']/1e9:.3f}"
            for s in r["collective_schedule"][:6])
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['mode']} | "
            f"{mem['temp_bytes']/1e9:.2f} | "
            f"{mem['argument_bytes']/1e9:.2f} | {sched or '—'} |")
    return hdr + "\n".join(rows) + "\n"


def roofline_table(recs) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL_FLOPS/HLO ratio |\n"
           "|---|---|---|---|---|---|---|\n")
    rows = []
    for r in recs:
        rf = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} | "
            f"{rf['memory_s']:.3e} | {rf['collective_s']:.3e} | "
            f"**{rf['dominant']}** | {rf['useful_ratio']:.3f} |")
    return hdr + "\n".join(rows) + "\n"


if __name__ == "__main__":
    recs = load(sys.argv[1])
    which = sys.argv[2] if len(sys.argv) > 2 else "both"
    if which in ("both", "dryrun"):
        print(dryrun_table(recs))
    if which in ("both", "roofline"):
        print(roofline_table(recs))
