"""End-to-end FL integration: CEFL recovers the planted client clusters,
improves accuracy over initialization, and costs a fraction of Regular
FL's communication — the paper's qualitative claims at test scale."""
import numpy as np
import pytest

from repro.core.fl import (FLConfig, FLHarness, run_cefl, run_fedper,
                           run_individual, run_regular_fl)
from repro.data.mobiact import make_client_datasets, slide_interval

CFG = FLConfig(n_clients=10, k_clusters=2, t_rounds=4, local_episodes=2,
               transfer_episodes=6, warmup_episodes=1, steps_per_episode=2,
               data_scale=0.25, eval_every=2, seed=3)


@pytest.fixture(scope="module")
def harness():
    return FLHarness(CFG)


def test_regular_fl_improves_and_syncs(harness):
    import jax
    import numpy as np
    r = run_regular_fl(harness, t_rounds=4)
    assert r.accuracy > 1.02 / 8         # above chance (tiny budget)
    assert r.comm_bytes > 0
    assert len(r.history) >= 2
    # functional sync check: regular FL must leave every client identical
    accs = r.per_client
    assert np.allclose(accs, accs[0], atol=1e-6)


def test_cefl_runs_and_saves_communication(harness):
    r_cefl = run_cefl(harness, t_rounds=3)
    r_reg = run_regular_fl(harness, t_rounds=3)
    assert r_cefl.comm_bytes < 0.35 * r_reg.comm_bytes
    assert r_cefl.accuracy > 1.2 / 8
    led = r_cefl.extras["ledger"]
    assert led.total == r_cefl.comm_bytes
    labels = r_cefl.extras["labels"]
    assert labels.shape == (harness.n,)
    assert labels.max() + 1 == 2
    # leaders are members of their own cluster
    for c, leader in enumerate(r_cefl.extras["leaders"]):
        assert labels[leader] == c


def test_individual_no_comm(harness):
    r = run_individual(harness, episodes=4)
    assert r.comm_bytes == 0


def test_fedper_between(harness):
    r_fp = run_fedper(harness, t_rounds=3)
    r_reg = run_regular_fl(harness, t_rounds=3)
    assert 0 < r_fp.comm_bytes < r_reg.comm_bytes


def test_similarity_clusters_planted_structure():
    """Clients trained on disjoint label subsets cluster together."""
    import jax
    from repro.core.louvain import cluster_clients
    from repro.core.similarity import layer_flatten, similarity_graph
    from repro.models import fd_cnn as F
    from repro.models.base import init_params
    from repro.optim.optimizers import make_optimizer

    data = make_client_datasets(8, seed=1, heterogeneity=0.0, scale=0.5)
    # plant structure deterministically: clients 0-3 share dataset X
    # (classes 0-3), clients 4-7 share dataset Y (classes 4-7), with a
    # touch of per-client noise.  Near-full-batch warm-up then makes
    # same-group weight trajectories align, so the similarity graph
    # (eq. 3-4) must recover the two populations.
    donor_a, donor_b = data.clients[0], data.clients[4]
    xa, ya = donor_a.x[donor_a.y < 4], donor_a.y[donor_a.y < 4]
    xb, yb = donor_b.x[donor_b.y >= 4], donor_b.y[donor_b.y >= 4]
    rng = np.random.RandomState(0)
    for i, c in enumerate(data.clients):
        x, y = (xa, ya) if i < 4 else (xb, yb)
        c.x = np.clip(x + 0.01 * rng.randn(*x.shape).astype(np.float32),
                      0, 1)
        c.y = y.copy()

    cfg = FLConfig(n_clients=8, warmup_episodes=8, steps_per_episode=4,
                   batch_size=min(64, len(ya), len(yb)), seed=0)
    h = FLHarness(cfg, data)
    params, opt, _ = h.local_train(h.params0, h.opt0, cfg.warmup_episodes)
    mats = layer_flatten(params, [params[n] for n in F.FD_CNN_LAYER_ORDER])
    S = np.asarray(similarity_graph(mats))
    labels = cluster_clients(S, 2)
    assert len(set(labels[:4].tolist())) == 1, (labels, S.round(2))
    assert len(set(labels[4:].tolist())) == 1, (labels, S.round(2))
    assert labels[0] != labels[7]


def test_eq10_slide_intervals():
    """Eq. 10: I_type scales linearly with recorded duration."""
    assert slide_interval("forward_lying") == 40          # t=10s → I_0
    assert slide_interval("daily_activity") == 2400       # t=600s → 60×I_0
    assert slide_interval("sit_chair") == 120


def test_synthetic_mobiact_shapes():
    data = make_client_datasets(4, seed=0, scale=0.2)
    assert len(data.clients) == 4
    for c in data.clients:
        assert c.x.shape[1:] == (20, 20, 3)
        assert c.x.shape[0] == c.y.shape[0] >= 8
        assert c.x.min() >= 0.0 and c.x.max() <= 1.0
    assert set(np.unique(data.test_y)) == set(range(8))


def test_related_work_baselines(harness):
    """FedPAQ + CMFL (paper §II) run and land between Individual and
    Regular FL on communication."""
    from repro.core.related import run_cmfl, run_fedpaq
    r_reg = run_regular_fl(harness, t_rounds=3)
    r_paq = run_fedpaq(harness, t_rounds=3, participation=0.5, bits=8)
    r_cm = run_cmfl(harness, t_rounds=3, threshold=0.45)
    assert 0 < r_paq.comm_bytes < r_reg.comm_bytes
    assert 0 < r_cm.comm_bytes <= r_reg.comm_bytes
    assert r_paq.accuracy > 1.0 / 8
    assert r_cm.accuracy > 1.0 / 8
    assert max(r_cm.extras["uploaded_per_round"]) <= harness.n
