"""Checkpoint round-trip: TrainState save → restore → bit-identical
continuation of training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.data.lm import synthetic_lm_batch
from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.steps import init_train_state, make_train_step


def test_roundtrip_and_identical_continuation(tmp_path):
    cfg = smoke_config("yi-6b")
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg))
    batch = jax.tree.map(jnp.asarray, synthetic_lm_batch(cfg, 2, 16, 0))

    state, _ = step(state, batch)
    save_checkpoint(str(tmp_path), 1, state)
    state_a, _ = step(state, batch)

    restored, got_step = restore_checkpoint(str(tmp_path),
                                            jax.eval_shape(lambda s: s, state))
    assert got_step == 1
    state_b, _ = step(restored, batch)
    for a, b in zip(jax.tree.leaves(state_a), jax.tree.leaves(state_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_mismatch_guard(tmp_path):
    cfg = smoke_config("xlstm-350m")
    state = init_train_state(cfg, jax.random.PRNGKey(1))
    save_checkpoint(str(tmp_path), 3, state)
    save_checkpoint(str(tmp_path), 7, state)
    assert latest_step(str(tmp_path)) == 7

    other = init_train_state(smoke_config("yi-6b"), jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), other)
