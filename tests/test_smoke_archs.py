"""Per-architecture smoke tests (deliverable f): a REDUCED variant of
each assigned family (2 layers, d_model ≤ 512, ≤ 4 experts) runs one
forward + one train step on CPU; output shapes + finiteness asserted.
The FULL configs are exercised only via the dry-run (no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, applicable_shapes, get_config, smoke_config
from repro.data.lm import synthetic_lm_batch
from repro.models import transformer as T
from repro.train.steps import init_train_state, make_train_step

ARCH_IDS = [a for a in ARCHS if a != "fd_cnn"]


def _batch(cfg, B, S, seed=0):
    return jax.tree.map(jnp.asarray, synthetic_lm_batch(cfg, B, S, seed))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch):
    cfg = smoke_config(arch)
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, aux = T.forward(cfg, params, batch)
    s_out = S if cfg.arch_type != "vlm" else S  # img+text = S total
    assert logits.shape == (B, s_out, cfg.vocab), logits.shape
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_loss_finite_and_decreases(arch):
    cfg = smoke_config(arch).with_(microbatch=2, learning_rate=3e-3)
    state = init_train_state(cfg, jax.random.PRNGKey(1))
    step = jax.jit(make_train_step(cfg))
    batch = _batch(cfg, 4, 16, seed=3)
    losses = []
    for _ in range(4):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0], losses  # same-batch refit must improve
    assert int(state.step) == 4


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).arch_type != "audio"])
def test_decode_matches_forward(arch):
    """prefill(S) + decode(S) logits == forward(S+1) last-token logits."""
    cfg = smoke_config(arch)
    params = T.init_model(cfg, jax.random.PRNGKey(2))
    B, S, W = 2, 16, 24
    batch = _batch(cfg, B, S + 1, seed=5)
    ref, _ = T.forward(cfg, params, batch)

    if cfg.arch_type == "vlm":
        pre = {"tokens": batch["tokens"][:, :S - cfg.n_img_tokens],
               "img_emb": batch["img_emb"]}
        nxt = batch["tokens"][:, S - cfg.n_img_tokens:S - cfg.n_img_tokens + 1]
    else:
        pre = {k: v[:, :S] for k, v in batch.items() if k != "labels"}
        nxt = batch["tokens"][:, S:S + 1]
    _, cache = T.prefill(cfg, params, pre, window=W)
    logits, _ = T.decode_step(cfg, params, cache, nxt, jnp.int32(S))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(ref[:, S]), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_applicable_shapes_catalog(arch):
    cfg = get_config(arch)
    shapes = applicable_shapes(cfg)
    if cfg.arch_type == "audio":            # encoder-only: no decode
        assert shapes == ["train_4k", "prefill_32k"]
    else:
        assert set(shapes) == {"train_4k", "prefill_32k", "decode_32k",
                               "long_500k"}


def test_exact_assigned_configs():
    """The 10 configs carry the exact assigned hyperparameters."""
    spec = {
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "yi-6b": (32, 4096, 32, 4, 11008, 64000),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
    }
    for name, (L, d, h, kv, ff, v) in spec.items():
        c = get_config(name)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
                c.d_ff, c.vocab) == (L, d, h, kv, ff, v), name
    assert get_config("qwen3-moe-235b-a22b").n_experts == 128
    assert get_config("qwen3-moe-235b-a22b").experts_per_token == 8
    assert get_config("granite-moe-3b-a800m").n_experts == 40
    assert get_config("zamba2-1.2b").ssm_state == 64
