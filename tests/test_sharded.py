"""Datacenter-scale CEFL semantics + collective-traffic validation on an
8-device test mesh (subprocess: jax fixes the host device count per
process, and the main test process must keep seeing 1 device)."""
import pytest

from tests.helpers import run_with_devices


def test_cefl_pod_semantics_and_collective_bytes():
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np, re
        from jax.sharding import PartitionSpec as P
        from repro.configs.registry import smoke_config
        from repro.core.sharded import (CEFLShardedConfig, init_pod_state,
                                        make_fl_round, sync_bytes_per_round)
        from repro.data.lm import synthetic_lm_batch
        from repro.launch.mesh import make_test_mesh
        from repro.launch.roofline import parse_collectives

        cfg = smoke_config('yi-6b')
        mesh = make_test_mesh(data=2, model=2, pods=2)

        def batches(seed):
            rows = []
            for s in range(2):
                pods = [synthetic_lm_batch(cfg, 4, 16, seed=seed+10*s+p)
                        for p in range(2)]
                rows.append(jax.tree.map(lambda *y: jnp.stack(y), *pods))
            return jax.tree.map(lambda *x: jnp.stack(x), *rows)

        def lower(mode):
            fl = CEFLShardedConfig(n_pods=2, inner_steps=2, mode=mode)
            rf = make_fl_round(cfg, fl)
            state = init_pod_state(cfg, jax.random.PRNGKey(0), 2)
            b = jax.tree.map(jnp.asarray, batches(0))
            state_ps = jax.tree.map(
                lambda x: P('pod'), state,
                is_leaf=lambda x: hasattr(x, 'shape'))
            batch_ps = jax.tree.map(
                lambda x: P(None, 'pod', 'data'), b,
                is_leaf=lambda x: hasattr(x, 'shape'))
            with jax.set_mesh(mesh):
                fn = jax.jit(rf, in_shardings=(state_ps, batch_ps),
                             out_shardings=(state_ps, {'loss': P()}))
                c = fn.lower(state, b).compile()
                r = fn(state, b)
            return c, r

        c_cefl, (st_c, m_c) = lower('cefl')
        c_reg, (st_r, m_r) = lower('regular')

        # semantics: base equal / personalized diverged across pods
        head = np.asarray(st_c.params['head']['w'])
        emb = np.asarray(st_c.params['embed']['tok'])
        assert np.allclose(emb[0], emb[1]), 'base must sync'
        assert not np.allclose(head[0], head[1]), 'personalized must stay local'
        head_r = np.asarray(st_r.params['head']['w'])
        assert np.allclose(head_r[0], head_r[1]), 'regular syncs everything'

        # collective traffic: cefl pod-sync moves fewer bytes than regular
        def pod_bytes(c):
            ops = parse_collectives(c.as_text(), 8, pod_size=4)
            return sum(o.link_bytes for o in ops if o.group_size > 1)
        b_cefl, b_reg = pod_bytes(c_cefl), pod_bytes(c_reg)
        assert b_cefl < b_reg, (b_cefl, b_reg)

        # predicted bytes ledger matches the mask fraction
        p_one = jax.tree.map(lambda x: x[0], st_c.params)
        pred_c = sync_bytes_per_round(cfg, p_one, 'cefl')
        pred_r = sync_bytes_per_round(cfg, p_one, 'regular')
        assert pred_c < pred_r
        print('OK', b_cefl, b_reg, pred_c, pred_r)
    """)
    assert "OK" in out


def test_train_step_lowering_on_test_mesh():
    """A reduced arch lowers + compiles with the production sharding rules
    on a small mesh, and the grad all-reduce appears in the HLO."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs.registry import smoke_config
        from repro.launch import specs as SP
        from repro.launch.mesh import make_test_mesh
        from repro.launch.roofline import parse_collectives
        from repro.train.steps import make_train_step

        cfg = smoke_config('qwen3-moe-235b-a22b').with_(microbatch=2)
        mesh = make_test_mesh(data=2, model=4)
        step = make_train_step(cfg)
        state_abs = SP.abstract_train_state(cfg)
        state_ps = SP.train_state_pspecs(cfg, mesh)
        batch_abs = {
            'tokens': jax.ShapeDtypeStruct((2, 4, 16), jnp.int32),
            'labels': jax.ShapeDtypeStruct((2, 4, 16), jnp.int32)}
        batch_ps = {'tokens': P(None, 'data'), 'labels': P(None, 'data')}
        with jax.set_mesh(mesh):
            c = jax.jit(step, in_shardings=(state_ps, batch_ps),
                        out_shardings=(state_ps,
                                       {'loss': P(), 'grad_norm': P(),
                                        'lr': P()})).lower(
                state_abs, batch_abs).compile()
        ops = parse_collectives(c.as_text(), 8)
        kinds = {o.kind for o in ops}
        assert kinds & {'all-reduce', 'reduce-scatter'}, kinds
        ma = c.memory_analysis()
        assert ma.temp_size_in_bytes > 0
        print('OK', sorted(kinds))
    """)
    assert "OK" in out


def test_serve_decode_lowering_on_test_mesh():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.configs.registry import smoke_config
        from repro.launch import specs as SP
        from repro.launch.mesh import make_test_mesh
        from repro.train.steps import make_decode_fn
        from repro.configs.base import INPUT_SHAPES

        # reduced arch but the real decode path + cache pspec machinery
        cfg = smoke_config('zamba2-1.2b')
        mesh = make_test_mesh(data=2, model=4)
        fn = make_decode_fn(cfg)
        from repro.models import transformer as T
        cache_abs = jax.eval_shape(lambda: T.init_cache(cfg, 8, 32))
        params_abs = SP.abstract_train_state(cfg).params
        params_ps = SP.serve_param_pspecs(cfg, mesh)
        toks = jax.ShapeDtypeStruct((8, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        with jax.set_mesh(mesh):
            c = jax.jit(fn).lower(params_abs, cache_abs, toks, pos).compile()
        print('OK decode lowered')
    """)
    assert "OK" in out
