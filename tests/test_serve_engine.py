"""Serving engine: continuous batching must produce exactly the tokens
sequential greedy decoding produces, for staggered arrivals and mixed
prompt lengths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine


def _greedy_reference(cfg, params, prompt, n_new):
    """Sequential greedy via full forward (oracle, O(S²) per token)."""
    toks = list(map(int, prompt))
    for _ in range(n_new):
        logits, _ = T.forward(cfg, params,
                              {"tokens": jnp.asarray([toks])})
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_config("yi-6b")
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_matches_sequential_greedy(setup):
    cfg, params = setup
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=n).astype(np.int32)
               for n in (7, 13, 5)]
    want = [_greedy_reference(cfg, params, p, 6) for p in prompts]

    eng = ServeEngine(cfg, params, batch_slots=2, window=64, prefill_pad=8)
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    ticks = eng.run(reqs)
    assert all(r.done for r in reqs)
    for r, w in zip(reqs, want):
        assert r.output == w, (r.rid, r.output, w)
    # 3 requests through 2 slots → continuous batching actually interleaved
    assert ticks >= 6


def test_engine_eos_frees_slot(setup):
    cfg, params = setup
    rng = np.random.RandomState(1)
    p = rng.randint(0, cfg.vocab, size=6).astype(np.int32)
    first = _greedy_reference(cfg, params, p, 1)[0]
    req = Request(0, p, max_new_tokens=50, eos_id=first)
    eng = ServeEngine(cfg, params, batch_slots=1, window=64, prefill_pad=8)
    eng.run([req])
    assert req.done
    assert req.output == [first]     # stopped at EOS immediately
    assert eng.active == 0
