"""Core CEFL machinery: similarity (eq. 3-4), Louvain clustering,
leader selection (eq. 5), base/personalized partition (Step 4), and the
communication-cost model (eq. 9) — exactness + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import comm_cost as CC
from repro.core.louvain import cluster_clients, louvain, modularity
from repro.core.partition import (fd_cnn_mask, mask_fraction,
                                  masked_interpolate, param_mask)
from repro.core.similarity import (distance_matrix, layer_flatten,
                                   select_leader, similarity_from_distance)


# ------------------------------------------------------------ similarity


def test_distance_matrix_eq3():
    """eq. 3: sum over layers of per-layer Euclidean distances."""
    n = 5
    l1 = jnp.asarray(np.random.RandomState(0).randn(n, 7))
    l2 = jnp.asarray(np.random.RandomState(1).randn(n, 3))
    d = np.asarray(distance_matrix([l1, l2]))
    for i in range(n):
        for j in range(n):
            want = (np.linalg.norm(np.asarray(l1)[i] - np.asarray(l1)[j])
                    + np.linalg.norm(np.asarray(l2)[i] - np.asarray(l2)[j]))
            # Gram-trick cancellation noise is ~1e-3 near zero distance
            assert abs(d[i, j] - want) < 2e-3


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 12), seed=st.integers(0, 50))
def test_similarity_eq4_properties(n, seed):
    w = jnp.asarray(np.random.RandomState(seed).randn(n, 6))
    d = distance_matrix([w])
    s = np.asarray(similarity_from_distance(d))
    dn = np.asarray(d)
    off = ~np.eye(n, dtype=bool)
    d_min, d_max = dn[off].min(), dn[off].max()
    # eq. 4 exactly, off-diagonal
    np.testing.assert_allclose(s[off], -dn[off] + d_min + d_max, rtol=1e-5)
    # similarity ordering inverts distance ordering
    assert s[off].max() == pytest.approx(-d_min + d_min + d_max, rel=1e-5)
    assert (s[off] >= d_min - 1e-5).all()


def test_leader_selection_eq5():
    S = np.array([[0, 10, 1, 1],
                  [10, 0, 1, 1],
                  [1, 1, 0, 9],
                  [1, 1, 9, 0]], float)
    assert select_leader(S, [0, 1]) in (0, 1)
    # client 2's intra-cluster similarity sum (9) vs 3 (9): tie→first max
    assert select_leader(S, [2, 3]) == 2
    assert select_leader(S, [1]) == 1
    # asymmetric case
    S2 = np.array([[0, 5, 2], [5, 0, 4], [2, 4, 0]], float)
    assert select_leader(S2, [0, 1, 2]) == 1   # row sums: 7, 9, 6


# -------------------------------------------------------------- louvain


def test_louvain_two_blocks():
    rng = np.random.RandomState(0)
    n = 16
    S = rng.rand(n, n) * 0.05
    S[:8, :8] += 1.0
    S[8:, 8:] += 1.0
    S = (S + S.T) / 2
    np.fill_diagonal(S, 0)
    labels = cluster_clients(S, 2)
    assert labels.max() + 1 == 2
    assert len(set(labels[:8])) == 1 and len(set(labels[8:])) == 1
    assert labels[0] != labels[8]


@pytest.mark.parametrize("k", [2, 3, 5])
def test_cluster_exact_k(k):
    rng = np.random.RandomState(1)
    S = rng.rand(20, 20)
    S = (S + S.T) / 2
    np.fill_diagonal(S, 0)
    labels = cluster_clients(S, k)
    assert labels.max() + 1 == k
    assert set(labels) == set(range(k))


def test_modularity_partition_beats_random():
    rng = np.random.RandomState(2)
    S = rng.rand(12, 12) * 0.05
    S[:6, :6] += 1.0
    S[6:, 6:] += 1.0
    S = (S + S.T) / 2
    np.fill_diagonal(S, 0)
    good = np.array([0] * 6 + [1] * 6)
    bad = np.array([0, 1] * 6)
    assert modularity(S, good) > modularity(S, bad)
    assert -0.5 <= modularity(S, good) <= 1.0


# ------------------------------------------------------------- partition


def test_fd_cnn_prefix_mask():
    from repro.models.base import init_params
    from repro.models.fd_cnn import fd_cnn_specs
    p = init_params(fd_cnn_specs(), jax.random.PRNGKey(0))
    m = fd_cnn_mask(p, base_layers=2)
    assert float(m["conv1"]["w"]) == 1.0 and float(m["conv2"]["w"]) == 1.0
    assert float(m["fc1"]["w"]) == 0.0 and float(m["fc2"]["w"]) == 0.0


def test_transformer_prefix_mask_and_interpolate():
    from repro.configs.registry import smoke_config
    from repro.models import transformer as T
    cfg = smoke_config("yi-6b")          # base_layers=1 of 2
    p = T.init_model(cfg, jax.random.PRNGKey(0))
    m = param_mask(cfg, p)
    blk = np.asarray(m["blocks"]["attn"]["wq"]).reshape(-1)
    assert blk[0] == 1.0 and blk[1] == 0.0
    assert float(np.asarray(m["embed"]["tok"])) == 1.0
    assert float(np.asarray(m["head"]["w"])) == 0.0
    new = jax.tree.map(jnp.zeros_like, p)
    mixed = masked_interpolate(m, new, p)
    assert np.allclose(np.asarray(mixed["blocks"]["attn"]["wq"])[0], 0.0)
    assert np.allclose(np.asarray(mixed["blocks"]["attn"]["wq"])[1],
                       np.asarray(p["blocks"]["attn"]["wq"])[1])


def test_moe_non_expert_mask():
    from repro.configs.registry import smoke_config
    from repro.models import transformer as T
    cfg = smoke_config("qwen3-moe-235b-a22b")   # base_predicate=non_expert
    p = T.init_model(cfg, jax.random.PRNGKey(0))
    m = param_mask(cfg, p)
    assert np.all(np.asarray(m["blocks"]["moe"]["wi"]) == 0.0)
    assert np.all(np.asarray(m["blocks"]["moe"]["router"]) == 1.0)
    assert np.all(np.asarray(m["blocks"]["attn"]["wq"]) == 1.0)
    frac = mask_fraction(m, p)
    assert 0.0 < frac < 0.7          # experts dominate the byte count


# -------------------------------------------------------------- comm cost


def test_eq9_exact():
    """Δ = (N+K)·Σ_L δ + T(K+1)·Σ_B δ, exactly."""
    delta = [100, 200, 300, 400]
    N, K, T, B = 67, 2, 100, 2
    led = CC.cefl_cost(delta, N, K, T, B)
    full, base = sum(delta), sum(delta[:B])
    assert led.total == (N + K) * full + T * (K + 1) * base
    assert led.clustering_upload == N * full
    assert led.fl_upload == K * T * base
    assert led.fl_broadcast == T * base
    assert led.transfer == K * full


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 200), k=st.integers(1, 10), t=st.integers(1, 500),
       b=st.integers(1, 4))
def test_eq9_property(n, k, t, b):
    delta = [228, 2432, 410112, 4104]     # FD-CNN fp32 layer bytes /4
    k = min(k, n)
    led = CC.cefl_cost(delta, n, k, t, b)
    assert led.total == ((n + k) * sum(delta)
                         + t * (k + 1) * sum(delta[:b]))
    # CEFL must beat regular FL for any T ≥ 1 once N >> K
    if n >= 20 and t >= 10:
        assert led.total < CC.regular_fl_cost(delta, n, t)


def test_paper_constants_savings():
    """Paper headline: ≥ 98% savings at N=67, K=2, T_cefl=100, T_reg=350."""
    from repro.models.fd_cnn import layer_sizes_bytes
    delta = list(layer_sizes_bytes().values())
    cefl = CC.cefl_cost(delta, 67, 2, 100, 3).total
    reg = CC.regular_fl_cost(delta, 67, 350)
    sav = CC.savings(cefl, reg)
    assert sav > 0.98, sav
    fp = CC.fedper_cost(delta, 67, 350, 3)
    assert cefl < fp < reg
