"""Roofline machinery: HLO collective parsing, the scan-body caveat that
motivates the analytic model, and analytic-vs-HLO cross-validation on an
unscanned variant where cost_analysis IS exact."""
import numpy as np
import pytest

from repro.launch.roofline import (CollectiveOp, parse_collectives,
                                   _result_bytes)
from tests.helpers import run_with_devices


def test_result_bytes_parsing():
    line = ("%all-reduce = f32[4,8]{1,0} all-reduce(%dot), channel_id=1, "
            "replica_groups=[2,4]<=[8], use_global_device_ids=true")
    assert _result_bytes(line) == 4 * 8 * 4
    line2 = "%ag = (bf16[16,8]{1,0}, bf16[16,8]{1,0}) all-gather-start(...)"
    # -start tuples: largest single buffer, not operand+result double count
    assert _result_bytes(line2) == 16 * 8 * 2


def test_parse_collectives_ring_factors():
    hlo = """
      %all-reduce = f32[100]{0} all-reduce(%x), replica_groups=[2,4]<=[8], foo
      %all-gather = bf16[64]{0} all-gather(%y), replica_groups=[4,2]<=[8], foo
      %cp = f32[10]{0} collective-permute(%z), replica_groups={{0,1},{2,3}}, foo
    """
    ops = parse_collectives(hlo, 8)
    ar = [o for o in ops if o.kind == "all-reduce"][0]
    assert ar.group_size == 4
    assert ar.link_bytes == pytest.approx(2 * 400 * 3 / 4)
    ag = [o for o in ops if o.kind == "all-gather"][0]
    assert ag.group_size == 2
    assert ag.link_bytes == pytest.approx(128 * 1 / 2)
    cp = [o for o in ops if o.kind == "collective-permute"][0]
    assert cp.link_bytes == 40


def test_pod_crossing_detection():
    hlo = "%ar = f32[8]{0} all-reduce(%x), replica_groups={{0,4},{1,5}}, f"
    ops = parse_collectives(hlo, 8, pod_size=4)
    assert ops[0].crosses_pod
    hlo2 = "%ar = f32[8]{0} all-reduce(%x), replica_groups={{0,1},{4,5}}, f"
    ops2 = parse_collectives(hlo2, 8, pod_size=4)
    assert not ops2[0].crosses_pod


def test_cost_analysis_counts_scan_body_once():
    """The documented caveat that motivates launch/analytic.py."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        def f(x, w):
            def body(h, _):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, None, length=10)
            return h.sum()
        x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        c = jax.jit(f).lower(x, w).compile()
        fl = c.cost_analysis()['flops']
        one_body = 2 * 64 * 128 * 128
        assert fl < 3 * one_body, (fl, one_body)   # NOT 10 bodies
        print('OK', fl)
    """, n_devices=1)
    assert "OK" in out


def test_analytic_matches_hlo_on_unscanned_variant():
    """Where cost_analysis is exact (no scans), the analytic FLOP model
    agrees within 25% (HLO includes softmax/norm flops we fold into the
    6ND margin)."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.configs.registry import smoke_config
        from repro.configs.base import InputShape, INPUT_SHAPES
        from repro.launch import analytic as A
        from repro.train.steps import make_train_step, init_train_state

        cfg = smoke_config('yi-6b').with_(scan_layers=False, microbatch=1,
                                          remat=False)
        B, S = 4, 128
        INPUT_SHAPES['__test'] = InputShape('__test', S, B, 'train')
        step = make_train_step(cfg)
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        batch = {'tokens': jax.ShapeDtypeStruct((B, S), jnp.int32),
                 'labels': jax.ShapeDtypeStruct((B, S), jnp.int32)}
        c = jax.jit(step).lower(
            jax.eval_shape(lambda s: s, state), batch).compile()
        hlo_fl = c.cost_analysis()['flops']
        ana_fl = A.step_flops(cfg, '__test')
        ratio = hlo_fl / ana_fl
        assert 0.75 < ratio < 1.35, (hlo_fl, ana_fl, ratio)
        print('OK ratio=%.3f' % ratio)
    """, n_devices=1)
    assert "OK" in out


def test_param_counts_sane():
    from repro.configs.registry import get_config
    from repro.launch.analytic import param_counts
    pc = param_counts(get_config("nemotron-4-340b"))
    assert 3.0e11 < pc["total"] < 3.8e11, pc        # ~340B
    pc = param_counts(get_config("yi-6b"))
    assert 5.5e9 < pc["total"] < 6.8e9, pc          # ~6B
    moe = param_counts(get_config("qwen3-moe-235b-a22b"))
    assert moe["active"] < 0.2 * moe["total"]       # a22b of 235b
    assert 1.8e11 < moe["total"] < 2.9e11
