"""Per-kernel validation: shape/dtype sweeps, interpret-mode Pallas vs
the pure-jnp oracles in kernels/ref.py, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


# ------------------------------------------------------------ pairwise


@pytest.mark.parametrize("n,p,bn,bp", [
    (8, 64, 8, 32), (67, 700, 32, 128), (16, 130, 8, 64),
    (128, 512, 128, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_dist_matches_ref(n, p, bn, bp, dtype):
    w = jax.random.normal(KEY, (n, p), jnp.float32).astype(dtype)
    got = ops.pairwise_dist(w, bn=bn, bp=bp)
    want = ref.pairwise_dist_ref(w.astype(jnp.float32))
    scale = float(jnp.max(want)) + 1e-6
    tol = 5e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got) / scale,
                               np.asarray(want) / scale, atol=tol)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 24), p=st.integers(1, 90), seed=st.integers(0, 99))
def test_pairwise_properties(n, p, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (n, p))
    d = np.asarray(ops.pairwise_dist(w, bn=8, bp=32))
    assert np.allclose(d, d.T, atol=1e-4)           # symmetry
    assert np.allclose(np.diag(d), 0.0, atol=1e-3)  # self-distance
    assert (d >= -1e-5).all()                       # non-negativity


# ---------------------------------------------------------- partial agg


@pytest.mark.parametrize("k,p,bp", [(2, 256, 128), (5, 2500, 256),
                                    (67, 4096, 1024)])
def test_partial_agg_matches_ref(k, p, bp):
    w = jax.random.normal(KEY, (k, p))
    a = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(1), (k,)))
    nchunks = -(-p // bp)
    gamma = (jnp.arange(nchunks) % 2).astype(jnp.float32)
    got = ops.partial_agg(w, a, gamma, self_idx=min(1, k - 1), bp=bp)
    wp = jnp.pad(w, ((0, 0), (0, nchunks * bp - p)))
    want = ref.partial_agg_ref(wp, a, gamma, min(1, k - 1), bp)[:p]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_partial_agg_gamma_semantics():
    """gamma=1 chunks equal the weighted mean; gamma=0 chunks keep own."""
    w = jnp.stack([jnp.full((256,), 1.0), jnp.full((256,), 3.0)])
    a = jnp.array([0.5, 0.5])
    out = ops.partial_agg(w, a, jnp.array([1.0, 0.0]), self_idx=1, bp=128)
    assert np.allclose(np.asarray(out[:128]), 2.0)
    assert np.allclose(np.asarray(out[128:]), 3.0)


# ------------------------------------------------------- flash attention


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 17), (False, 9)])
@pytest.mark.parametrize("s,h,kv,d", [(64, 4, 4, 32), (100, 8, 2, 32),
                                      (130, 4, 1, 64)])
def test_flash_attention_matches_ref(causal, window, s, h, kv, d):
    q = jax.random.normal(KEY, (2, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, s, kv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, s, kv, d))
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              bq=32, bk=32)
    g = h // kv
    def expand(t):
        return jnp.repeat(t.transpose(0, 2, 1, 3), g, 1).reshape(2 * h, s, d)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(2 * h, s, d), expand(k), expand(v),
        causal=causal, window=window).reshape(2, h, s, d).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(KEY, (1, 64, 2, 32)).astype(dtype)
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 2, 32)).astype(dtype)
    v = jax.random.normal(jax.random.PRNGKey(4), (1, 64, 2, 32)).astype(dtype)
    got = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
    assert got.dtype == dtype
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(2, 64, 32),
        k.transpose(0, 2, 1, 3).reshape(2, 64, 32),
        v.transpose(0, 2, 1, 3).reshape(2, 64, 32),
        causal=True).reshape(1, 2, 64, 32).transpose(0, 2, 1, 3)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_flash_matches_model_attention():
    """Kernel agrees with the model's einsum attention path end-to-end."""
    from repro.configs.registry import smoke_config
    from repro.models import layers as L
    from repro.models.base import init_params

    cfg = smoke_config("yi-6b")
    p = init_params(L.attn_params(cfg), KEY)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model))
    want = L.full_attention(cfg, p, x)
    q, k, v = L._qkv(cfg, p, x, jnp.arange(32)[None, :])
    got_heads = ops.flash_attention(q, k, v, causal=True, bq=16, bk=16)
    got = jnp.einsum("bshk,hkd->bsd", got_heads, p["wo"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-2, rtol=1e-3)


# ------------------------------------------------------ decode attention


@pytest.mark.parametrize("w,h,kv,d,pos", [(64, 4, 2, 32, 20),
                                          (96, 8, 8, 32, 95),
                                          (64, 4, 1, 64, 200)])
def test_decode_attention_matches_ref(w, h, kv, d, pos):
    b = 2
    q = jax.random.normal(KEY, (b, 1, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, w, kv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, w, kv, d))
    got = ops.decode_attention(q, k, v, jnp.int32(pos), bk=32)
    want = ref.decode_attention_ref(q, k, v, jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


def test_decode_attention_matches_model_layer():
    """Kernel agrees with layers.decode_attention end-to-end."""
    from repro.configs.registry import smoke_config
    from repro.models import layers as L
    from repro.models.base import init_params

    cfg = smoke_config("yi-6b")
    p = init_params(L.attn_params(cfg), KEY)
    B, W, pos = 2, 32, 20
    x = jax.random.normal(KEY, (B, 1, cfg.d_model))
    ck = jax.random.normal(jax.random.PRNGKey(5), (B, W, cfg.n_kv_heads, cfg.hd))
    cv = jax.random.normal(jax.random.PRNGKey(6), (B, W, cfg.n_kv_heads, cfg.hd))
    want, nk, nv = L.decode_attention(cfg, p, x, ck, cv, jnp.int32(pos))
    q, _, _ = L._qkv(cfg, p, x, jnp.full((B, 1), pos, jnp.int32))
    got_h = ops.decode_attention(q, nk, nv, jnp.int32(pos), bk=32)
    got = jnp.einsum("bshk,hkd->bsd", got_h, p["wo"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-3)
