import os

# Tests see ONE device (smoke tests / kernels); mesh-dependent tests run
# in subprocesses with their own XLA_FLAGS (see tests/helpers.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)
