"""§Perf lever correctness: every beyond-paper optimization must keep
the math (checkpointed chunked loss/attention are exact; fp8 paths bound
the error) — these guard the hillclimb changes recorded in EXPERIMENTS.md."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.data.lm import synthetic_lm_batch
from repro.models import transformer as T
from repro.train.steps import init_train_state, lm_loss, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B, S, seed=0):
    return jax.tree.map(jnp.asarray, synthetic_lm_batch(cfg, B, S, seed))


def test_chunked_loss_exact():
    cfg = smoke_config("yi-6b")
    params = T.init_model(cfg, KEY)
    batch = _batch(cfg, 2, 17)
    l0, _ = lm_loss(cfg, params, batch)
    l1, _ = lm_loss(cfg.with_(loss_seq_chunk=5), params, batch)
    assert abs(float(l0) - float(l1)) < 1e-5


def test_chunked_loss_gradients_match():
    cfg = smoke_config("yi-6b")
    params = T.init_model(cfg, KEY)
    batch = _batch(cfg, 2, 16)
    g0 = jax.grad(lambda p: lm_loss(cfg, p, batch)[0])(params)
    g1 = jax.grad(lambda p: lm_loss(cfg.with_(loss_seq_chunk=4), p, batch)[0]
                  )(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_q_chunked_attention_exact():
    cfg = smoke_config("yi-6b")
    params = T.init_model(cfg, KEY)
    batch = _batch(cfg, 2, 32)
    l0, _ = T.forward(cfg, params, batch)
    l1, _ = T.forward(cfg.with_(attn_q_chunk=8), params, batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               atol=1e-4, rtol=1e-3)


def test_q_chunked_attention_gradients_match():
    cfg = smoke_config("yi-6b")
    params = T.init_model(cfg, KEY)
    batch = _batch(cfg, 2, 16)
    g0 = jax.grad(lambda p: lm_loss(cfg, p, batch)[0])(params)
    g1 = jax.grad(lambda p: lm_loss(cfg.with_(attn_q_chunk=4), p, batch)[0]
                  )(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-4)


def test_fp8_kv_cache_decode_agreement():
    """int8+scales KV cache: per-element cache error ~0.4%, decode logits
    close on a 1-layer model.  (Deeper UNTRAINED smoke stacks amplify
    score-level noise through softmax — |k| grows to ~30 — which is an
    artifact of random weights, not of the quantizer; recorded in
    EXPERIMENTS.md §Perf pair C.)"""
    cfg = smoke_config("yi-6b").with_(n_layers=1)
    cfg8 = cfg.with_(cache_dtype=jnp.int8)
    params = T.init_model(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 33), 0, cfg.vocab)
    _, c0 = T.prefill(cfg, params, {"tokens": toks[:, :32]}, window=48)
    _, c8 = T.prefill(cfg8, params, {"tokens": toks[:, :32]}, window=48)
    assert c8["k"].dtype == jnp.int8
    assert "k_scale" in c8
    l0, _ = T.decode_step(cfg, params, c0, toks[:, 32:33], jnp.int32(32))
    l8, _ = T.decode_step(cfg8, params, c8, toks[:, 32:33], jnp.int32(32))
    # untrained smoke logits are nearly flat, so argmax is not a fair
    # agreement metric; bound the relative logit perturbation instead
    rel = float(jnp.max(jnp.abs(l8 - l0)) / (jnp.max(jnp.abs(l0)) + 1e-6))
    assert rel < 0.1, rel
    # quantizer itself: sub-percent element error
    kk0 = np.asarray(c0["k"], np.float32)
    kk8 = np.asarray(c8["k"], np.float32) / 127.0 * np.asarray(c8["k_scale"])
    el = np.max(np.abs(kk0 - kk8)) / (np.max(np.abs(kk0)) + 1e-6)
    assert el < 0.01, el


def test_fp8_moe_dispatch_trains():
    cfg = smoke_config("qwen3-moe-235b-a22b").with_(
        moe_dispatch_dtype=jnp.float8_e4m3fn, microbatch=1)
    state = init_train_state(cfg, KEY)
    step = jax.jit(make_train_step(cfg))
    b = _batch(cfg, 4, 16, seed=1)
    losses = []
    for _ in range(3):
        state, m = step(state, b)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


def test_seq_parallel_noop_without_mesh():
    """seq_parallel is a sharding hint only — numerics unchanged."""
    cfg = smoke_config("yi-6b")
    params = T.init_model(cfg, KEY)
    batch = _batch(cfg, 2, 16)
    l0, _ = T.forward(cfg, params, batch)
    l1, _ = T.forward(cfg.with_(seq_parallel=True), params, batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1))
