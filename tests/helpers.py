"""Run mesh-dependent test payloads in a subprocess with a forced
multi-device host platform (jax locks the device count per process)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout
