"""Model-substrate correctness: MoE dispatch vs per-token oracle, the
chunked SSD/mLSTM scans vs naive sequential recurrences, rolling-buffer
sliding-window decode, and optimizer reference checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL
from repro.models import transformer as T
from repro.models.base import init_params

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------ MoE


def test_moe_matches_per_token_oracle():
    cfg = smoke_config("qwen3-moe-235b-a22b").with_(capacity_factor=8.0)
    p = init_params(MOE.moe_params(cfg), KEY)
    x = jax.random.normal(KEY, (2, 8, cfg.d_model))
    got, aux = MOE.apply_moe(cfg, p, x)

    # oracle: loop tokens, run top-k experts densely
    logits = np.asarray(jnp.einsum("bsd,de->bse", x, p["router"]))
    want = np.zeros_like(np.asarray(got))
    for b in range(2):
        for s in range(8):
            pr = np.exp(logits[b, s] - logits[b, s].max())
            pr = pr / pr.sum()
            top = np.argsort(-pr)[:cfg.experts_per_token]
            gates = pr[top] / pr[top].sum()
            tok = np.asarray(x)[b, s]
            acc = np.zeros(cfg.d_model, np.float32)
            for g, e in zip(gates, top):
                h = tok @ np.asarray(p["wi"])[e]
                gt = tok @ np.asarray(p["wg"])[e]
                h = h / (1 + np.exp(-h)) * gt
                acc += g * (h @ np.asarray(p["wo"])[e])
            want[b, s] = acc
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-4, rtol=1e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With capacity_factor → tiny, most tokens are dropped, output ≈ 0.
    (capacity() floors at 128 slots per expert — shardable over the data
    axis — so the test needs enough tokens to exceed the floor.)"""
    cfg = smoke_config("qwen3-moe-235b-a22b").with_(capacity_factor=1e-6)
    p = init_params(MOE.moe_params(cfg), KEY)
    x = jax.random.normal(KEY, (2, 512, cfg.d_model))
    full, _ = MOE.apply_moe(cfg, p, x)
    nrm_dropped = float(jnp.mean(jnp.sum(full ** 2, -1) == 0.0))
    assert nrm_dropped > 0.4     # most tokens got nothing back


def test_moe_token_chunking_invariant():
    cfg = smoke_config("qwen3-moe-235b-a22b").with_(capacity_factor=8.0)
    p = init_params(MOE.moe_params(cfg), KEY)
    x = jax.random.normal(KEY, (4, 16, cfg.d_model))
    ref, _ = MOE._moe_tokens(cfg, p, x)
    old = MOE.MOE_TOKEN_CHUNK
    try:
        MOE.MOE_TOKEN_CHUNK = 16          # force 4 chunks
        got, _ = MOE.apply_moe(cfg, p, x)
    finally:
        MOE.MOE_TOKEN_CHUNK = old
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-4, rtol=1e-3)


# ------------------------------------------------------------------ SSD


def _naive_ssm(cfg, p, x):
    """Sequential reference for the chunked SSD path."""
    B, L, d = x.shape
    d_inner, H, P, N = SSM._dims(cfg)
    proj = np.asarray(jnp.einsum("bld,de->ble", x, p["in_proj"]))
    z, xbc, dt_raw = (np.asarray(a) for a in SSM._split_proj(cfg, jnp.asarray(proj)))
    xbc_t = np.asarray(SSM._causal_conv(jnp.asarray(xbc), p["conv"]))
    xs, Bm, Cm = (xbc_t[..., :d_inner], xbc_t[..., d_inner:d_inner + N],
                  xbc_t[..., d_inner + N:])
    xs = xs.reshape(B, L, H, P)
    dt = np.log1p(np.exp(dt_raw + np.asarray(p["dt_bias"])))
    a = -np.exp(np.asarray(p["a_log"]))
    y = np.zeros((B, L, H, P), np.float32)
    for b in range(B):
        S = np.zeros((H, P, N), np.float32)
        for t in range(L):
            decay = np.exp(dt[b, t] * a)                    # (H,)
            S = S * decay[:, None, None] + dt[b, t][:, None, None] * \
                np.einsum("hp,n->hpn", xs[b, t], Bm[b, t])
            y[b, t] = np.einsum("n,hpn->hp", Cm[b, t], S)
    y = y + np.asarray(p["d_skip"])[None, None, :, None] * xs
    y = y.reshape(B, L, d_inner) * (np.asarray(z) / (1 + np.exp(-np.asarray(z))))
    return y @ np.asarray(p["out_proj"])


def test_ssd_chunked_matches_sequential():
    cfg = smoke_config("zamba2-1.2b").with_(ssm_chunk=4)
    p = init_params(SSM.ssm_params(cfg), KEY)
    x = jax.random.normal(KEY, (2, 12, cfg.d_model)) * 0.5
    got, cache = SSM.apply_ssm(cfg, p, x)
    want = _naive_ssm(cfg, p, x)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-3, rtol=1e-2)


def test_ssm_decode_continues_prefill():
    cfg = smoke_config("zamba2-1.2b").with_(ssm_chunk=4)
    p = init_params(SSM.ssm_params(cfg), KEY)
    x = jax.random.normal(KEY, (1, 9, cfg.d_model)) * 0.5
    full, _ = SSM.apply_ssm(cfg, p, x)
    part, cache = SSM.apply_ssm(cfg, p, x[:, :8])
    y, st, buf = SSM.decode_ssm(cfg, p, x[:, 8:9], cache["state"],
                                cache["conv"])
    np.testing.assert_allclose(np.asarray(y[:, 0]), np.asarray(full[:, 8]),
                               atol=2e-3, rtol=1e-2)


# ---------------------------------------------------------------- xLSTM


def test_mlstm_chunked_matches_decode_recurrence():
    cfg = smoke_config("xlstm-350m").with_(ssm_chunk=4)
    p = init_params(XL.mlstm_params(cfg), KEY)
    x = jax.random.normal(KEY, (2, 12, cfg.d_model)) * 0.5
    full, _ = XL.apply_mlstm(cfg, p, x)
    # sequential: decode step by step
    st = XL.init_mlstm_state(cfg, 2)
    outs = []
    for t in range(12):
        y, st = XL.decode_mlstm(cfg, p, x[:, t:t + 1], st)
        outs.append(y[:, 0])
    want = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(want),
                               atol=2e-3, rtol=1e-2)


def test_slstm_state_continuity():
    cfg = smoke_config("xlstm-350m")
    p = init_params(XL.slstm_params(cfg), KEY)
    x = jax.random.normal(KEY, (2, 10, cfg.d_model)) * 0.5
    full, _ = XL.apply_slstm(cfg, p, x)
    part, st = XL.apply_slstm(cfg, p, x[:, :7])
    rest, _ = XL.apply_slstm(cfg, p, x[:, 7:], st)
    np.testing.assert_allclose(np.asarray(full[:, 7:]), np.asarray(rest),
                               atol=1e-4, rtol=1e-3)


# ----------------------------------------------------- sliding window


def test_sliding_window_rolling_buffer_multi_wrap():
    cfg = smoke_config("yi-6b").with_(sliding_window=8)
    params = T.init_model(cfg, jax.random.PRNGKey(2))
    B, S, W = 2, 20, 8
    toks = jax.random.randint(KEY, (B, 30), 0, cfg.vocab)
    ref, _ = T.forward(cfg, params, {"tokens": toks})
    _, cache = T.prefill(cfg, params, {"tokens": toks[:, :S]}, window=W)
    for i in range(S, 29):
        logits, cache = T.decode_step(cfg, params, cache,
                                      toks[:, i:i + 1], jnp.int32(i))
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(ref[:, i]),
                                   atol=5e-3, rtol=5e-3)


# -------------------------------------------------------------- optimizers


def test_adam_matches_reference():
    from repro.optim.optimizers import adam
    opt = adam(b1=0.9, b2=0.999, eps=1e-8)
    p = {"w": jnp.ones((3,))}
    s = opt.init(p)
    g = {"w": jnp.array([1.0, 2.0, 3.0])}
    p1, s1 = opt.update(g, s, p, 0.1)
    # step 1: mhat = g, vhat = g², upd = g/|g| → 0.1 each
    np.testing.assert_allclose(np.asarray(p1["w"]),
                               1.0 - 0.1 * np.ones(3), atol=1e-5)
    assert int(s1.step) == 1


def test_adafactor_factored_state_shapes():
    from repro.optim.optimizers import adafactor
    opt = adafactor()
    p = {"w": jnp.ones((4, 6)), "b": jnp.ones((5,))}
    s = opt.init(p)
    assert s.nu["w"]["row"].shape == (4,)
    assert s.nu["w"]["col"].shape == (6,)
    assert s.nu["b"].shape == (5,)
    g = jax.tree.map(jnp.ones_like, p)
    p1, s1 = opt.update(g, s, p, 0.01)
    assert p1["w"].shape == (4, 6)
    assert np.isfinite(np.asarray(p1["w"])).all()
    assert float(jnp.max(jnp.abs(p1["w"] - p["w"]))) > 0


def test_grad_clip():
    from repro.optim.optimizers import clip_by_global_norm, global_norm
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)
