"""CEFL as a datacenter-scale partial-synchronization training protocol.

Re-reading the paper on a TPU mesh (DESIGN.md §3): a *client* is a pod
(replica group) holding its own full model copy; conventional FL is
plain cross-pod DDP; CEFL becomes

  * ε local train steps per round, synchronized only *within* the pod
    (the `data` axis all-reduce that pjit inserts automatically),
  * one cross-pod aggregation per round restricted to the *base-layer
    mask* and to *leader* pods (eq. 6–7 → a masked mean over the pod
    dim, which XLA lowers to an all-reduce over the `pod` mesh axis),
  * a one-shot *transfer* collective shipping leader weights to member
    pods (eq. 8 → gather over the pod dim).

Mechanically: every state leaf carries a leading ``n_pods`` dim sharded
over the mesh's `pod` axis, the per-pod train step is `vmap`ped over it,
and the sync is ordinary masked arithmetic over that dim — GSPMD turns
exactly the masked portion into cross-pod collective traffic, which is
what the roofline's collective term then measures.  The same functions
run unsharded on CPU for the semantic tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.partition import param_mask
from repro.train.steps import TrainState, init_train_state, make_train_step


@dataclasses.dataclass(frozen=True)
class CEFLShardedConfig:
    n_pods: int = 2
    inner_steps: int = 8            # ε: local steps between syncs
    mode: str = "cefl"              # cefl | regular | local_only
    leader_pods: tuple[int, ...] | None = None   # default: all pods lead


def _pod_mask_tree(cfg: ModelConfig, params_one):
    """Base mask with a broadcast pod dim prepended to each leaf."""
    mask = param_mask(cfg, params_one)
    return jax.tree.map(
        lambda m: m[None] if getattr(m, "ndim", 0) > 0 else m, mask)


def init_pod_state(cfg: ModelConfig, key, n_pods: int) -> TrainState:
    keys = jax.random.split(key, n_pods)
    return jax.vmap(lambda k: init_train_state(cfg, k))(keys)


def make_fl_round(cfg: ModelConfig, fl: CEFLShardedConfig,
                  train_step: Callable | None = None):
    """Build ``round_fn(state, batches) -> (state, metrics)``.

    ``state`` leaves have leading dim ``n_pods``; ``batches`` leaves are
    (inner_steps, n_pods, per_pod_batch, ...).
    """
    step = train_step or make_train_step(cfg)
    vstep = jax.vmap(step)
    leaders = fl.leader_pods or tuple(range(fl.n_pods))
    lead = jnp.zeros((fl.n_pods,), jnp.float32).at[jnp.asarray(leaders)].set(1.0)

    def _aggregate(p):
        """Masked mean over the pod dim, adopted by leader pods (eq. 6-7)."""
        w = lead.reshape((-1,) + (1,) * (p.ndim - 1))
        avg = jnp.sum(p.astype(jnp.float32) * w, axis=0, keepdims=True) \
            / jnp.sum(lead)
        adopted = w * avg + (1.0 - w) * p.astype(jnp.float32)
        return adopted.astype(p.dtype)

    def sync(params, mask_tree):
        """The base mask is static (pure function of cfg), so the skip
        decision is made at TRACE time: personalized leaves never enter a
        collective at all — this is what makes CEFL's cross-pod byte
        saving visible in the compiled HLO rather than relying on XLA to
        fold a multiply-by-zero around an all-reduce."""
        import numpy as np

        def leaf(m, p):
            m_np = np.asarray(m, np.float32).reshape(-1)
            if m_np.max() == 0.0:          # fully personalized: local
                return p
            if m_np.min() == 1.0:          # fully base: aggregate whole leaf
                return _aggregate(p)
            # per-layer prefix on a scan-stacked leaf (pod, L, ...):
            # aggregate the static base slice only (contiguous by
            # construction of the prefix predicate)
            b = int(m_np.sum())
            assert m_np[:b].min() == 1.0 and m_np[b:].max() == 0.0, \
                "non-contiguous partial mask"
            base = _aggregate(p[:, :b])
            return jnp.concatenate([base, p[:, b:]], axis=1)
        return jax.tree.map(leaf, mask_tree, params)

    def round_fn(state: TrainState, batches):
        def inner(s, b):
            s, metrics = vstep(s, b)
            return s, metrics["loss"]
        state, losses = jax.lax.scan(inner, state, batches)

        if fl.mode == "local_only":
            return state, {"loss": losses.mean()}
        params_one = jax.tree.map(lambda x: x[0], state.params)
        if fl.mode == "regular":
            import numpy as np
            mask_tree = jax.tree.map(np.ones_like,
                                     _pod_mask_tree(cfg, params_one))
        else:
            mask_tree = _pod_mask_tree(cfg, params_one)
        new_params = sync(state.params, mask_tree)
        return TrainState(state.step, new_params, state.opt_state), \
            {"loss": losses.mean()}

    return round_fn


def make_transfer(cfg: ModelConfig, fl: CEFLShardedConfig,
                  leader_of: tuple[int, ...]):
    """Eq. 8 at pod scale: member pods inherit their leader pod's model."""
    src = jnp.asarray(leader_of)

    def transfer(state: TrainState) -> TrainState:
        new_params = jax.tree.map(lambda x: x[src], state.params)
        return TrainState(state.step, new_params, state.opt_state)

    return transfer


# -------------------------------------------------------- byte accounting


def sync_bytes_per_round(cfg: ModelConfig, params_one, mode: str) -> int:
    """Predicted cross-pod collective bytes per FL round (eq. 9 analogue).

    CEFL moves only base-mask bytes once per round; regular DDP moves the
    full gradient set every inner step.  Verified against HLO collective
    parsing in tests/test_sharded.py.
    """
    import numpy as np
    mask = param_mask(cfg, params_one)
    total = 0
    for m, p in zip(jax.tree.leaves(mask), jax.tree.leaves(params_one)):
        frac = float(np.mean(np.asarray(m, np.float32)))
        n = int(np.prod(p.shape)) * p.dtype.itemsize
        total += int(frac * n) if mode == "cefl" else n
    return total
