"""Communication-cost model — paper eq. 9, exactly.

    Δ = N·Σ_{l≤L} δ_l  +  K·T·Σ_{l≤B} δ_l  +  T·Σ_{l≤B} δ_l  +  K·Σ_{l≤L} δ_l
      = (N+K)·Σ_{l≤L} δ_l + T·(K+1)·Σ_{l≤B} δ_l

The four terms: (1) every client uploads its warm-up weights once for
clustering; (2) leaders upload base layers each FL round; (3) the server
broadcasts base layers each round; (4) each leader ships the full model
to its cluster once for transfer learning.

Also provides the byte accounting for the baselines in Table I and the
datacenter-scale reading (collective bytes per training round).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CommLedger:
    clustering_upload: int
    fl_upload: int
    fl_broadcast: int
    transfer: int

    @property
    def total(self) -> int:
        return (self.clustering_upload + self.fl_upload
                + self.fl_broadcast + self.transfer)

    def megabytes(self) -> float:
        return self.total / 1e6


def cefl_cost(layer_bytes: list[int], n_clients: int, k: int, t_rounds: int,
              base_layers: int) -> CommLedger:
    """Eq. 9 decomposed into its four terms (bytes)."""
    full = sum(layer_bytes)
    base = sum(layer_bytes[:base_layers])
    return CommLedger(
        clustering_upload=n_clients * full,
        fl_upload=k * t_rounds * base,
        fl_broadcast=t_rounds * base,
        transfer=k * full,
    )


def regular_fl_cost(layer_bytes: list[int], n_clients: int, t_rounds: int,
                    per_client_broadcast: bool = True) -> int:
    """Conventional FL: every round all N clients upload the full model
    and the server sends the update back.

    ``per_client_broadcast=True`` counts the downlink once per client
    (T·2N·full) — this is the convention that reproduces the paper's
    Table I figure of 79 730 MB for Regular FL (N=67, T=350, fp32
    FD-CNN); eq. 9's CEFL broadcast term by contrast counts the shared
    broadcast once.  Set False for the single-broadcast convention.
    """
    full = sum(layer_bytes)
    down = n_clients * full if per_client_broadcast else full
    return t_rounds * (n_clients * full + down)


def fedper_cost(layer_bytes: list[int], n_clients: int, t_rounds: int,
                base_layers: int, per_client_broadcast: bool = True) -> int:
    """FedPer: all N clients participate but only base layers transit."""
    base = sum(layer_bytes[:base_layers])
    down = n_clients * base if per_client_broadcast else base
    return t_rounds * (n_clients * base + down)


def individual_cost() -> int:
    return 0


def savings(cefl: int, baseline: int) -> float:
    """Fractional savings vs a baseline (paper headline: 98.45 %)."""
    return 1.0 - cefl / baseline
