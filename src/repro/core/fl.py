"""Federated-learning orchestrators: CEFL (paper Algorithm 1 + transfer
session) and the three baselines of Table I (Regular FL, FedPer,
Individual Training), over FD-CNN + synthetic MobiAct.

TPU-native structure: all N clients' models live as ONE client-stacked
pytree (leading dim N) and local training is a single `vmap`ped SPMD
program — batching many tiny models instead of looping (DESIGN.md §3).

An "episode" is ``steps_per_episode`` minibatch Adam steps on the
client's own data (the paper's episode ≈ local epoch; datasets are
small so a few steps ≈ one epoch).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm_cost as CC
from repro.core.louvain import cluster_clients
from repro.core.partition import fd_cnn_mask, masked_interpolate
from repro.core.similarity import (layer_flatten, select_leader,
                                   similarity_graph)
from repro.data.mobiact import SyntheticMobiAct, make_client_datasets
from repro.models import fd_cnn as F
from repro.models.base import init_params
from repro.optim.optimizers import make_optimizer


@dataclasses.dataclass
class FLConfig:
    n_clients: int = 67
    k_clusters: int = 2
    t_rounds: int = 100            # T: FL rounds
    local_episodes: int = 8        # ε: episodes per FL round
    transfer_episodes: int = 350   # η: member fine-tune budget
    warmup_episodes: int = 2       # pre-clustering local training
    steps_per_episode: int = 4
    batch_size: int = 32
    lr: float = 1e-4
    base_layers: int = 2           # B (of FD-CNN's 4 CEFL layers)
    seed: int = 0
    heterogeneity: float = 0.5
    data_scale: float = 1.0
    use_kernel: bool = False       # Pallas pairwise-distance kernel
    eval_every: int = 5


# ---------------------------------------------------------------- harness


class FLHarness:
    """Shared machinery: stacked client params, vmapped local training."""

    def __init__(self, cfg: FLConfig, data: SyntheticMobiAct | None = None):
        self.cfg = cfg
        self.data = data or make_client_datasets(
            cfg.n_clients, cfg.seed, cfg.heterogeneity, cfg.data_scale)
        self.n = len(self.data.clients)
        self.opt = make_optimizer("adam")
        self.rng = np.random.RandomState(cfg.seed + 7)

        # Conventional FL: every client starts from the SAME server-
        # broadcast initialization (paper §III).  This also makes the
        # similarity graph meaningful — post-warm-up weight distances then
        # reflect the clients' data, not their random inits (eq. 3).
        key = jax.random.PRNGKey(cfg.seed)
        specs = F.fd_cnn_specs()
        one = init_params(specs, key)
        self.params0 = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (self.n,) + x.shape), one)
        self.opt0 = jax.vmap(self.opt.init)(self.params0)

        self._train_many = jax.jit(self._make_train_many())
        self._eval_one = jax.jit(F.fd_cnn_accuracy)
        self.test_batch = {"x": jnp.asarray(self.data.test_x),
                           "y": jnp.asarray(self.data.test_y)}
        self.sizes = np.array([len(c) for c in self.data.clients], np.float32)

    # ------------------------------------------------------ local training

    def _make_train_many(self):
        opt, lr = self.opt, self.cfg.lr

        def one_client(params, opt_state, xs, ys):
            def step(carry, b):
                p, s = carry
                loss, g = jax.value_and_grad(F.fd_cnn_loss)(
                    p, {"x": b[0], "y": b[1]})
                p, s = opt.update(g, s, p, lr)
                return (p, s), loss
            (params, opt_state), losses = jax.lax.scan(
                step, (params, opt_state), (xs, ys))
            return params, opt_state, losses.mean()

        return jax.vmap(one_client)

    def sample_batches(self, episodes: int, client_ids=None):
        """(N, steps, batch, ...) stacked minibatches from each client."""
        cfg = self.cfg
        ids = range(self.n) if client_ids is None else client_ids
        steps = episodes * cfg.steps_per_episode
        xs, ys = [], []
        for i in ids:
            c = self.data.clients[i]
            sel = self.rng.randint(0, len(c), size=(steps, cfg.batch_size))
            xs.append(c.x[sel])
            ys.append(c.y[sel])
        return jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys))

    def local_train(self, params, opt_state, episodes: int, client_ids=None):
        xs, ys = self.sample_batches(episodes, client_ids)
        return self._train_many(params, opt_state, xs, ys)

    # ---------------------------------------------------------- evaluation

    def eval_all(self, stacked_params) -> np.ndarray:
        """Per-client accuracy on the shared test set."""
        accs = jax.vmap(lambda p: self._eval_one(p, self.test_batch))(
            stacked_params)
        return np.asarray(accs)

    # --------------------------------------------------------- aggregation

    @staticmethod
    def aggregate(stacked, weights):
        """Eq. 2/6: weighted average over the leading client dim."""
        w = jnp.asarray(weights, jnp.float32)
        w = w / w.sum()
        return jax.tree.map(
            lambda x: jnp.tensordot(w, x.astype(jnp.float32), axes=1
                                    ).astype(x.dtype), stacked)

    @staticmethod
    def broadcast(avg, n):
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), avg)

    @staticmethod
    def gather(stacked, ids):
        idx = jnp.asarray(ids)
        return jax.tree.map(lambda x: x[idx], stacked)

    @staticmethod
    def scatter(stacked, ids, values):
        idx = jnp.asarray(ids)
        return jax.tree.map(lambda x, v: x.at[idx].set(v), stacked, values)


# ------------------------------------------------------------ the methods


@dataclasses.dataclass
class FLResult:
    name: str
    accuracy: float                  # mean client accuracy, final
    per_client: np.ndarray
    history: list[tuple[int, float]]     # (episode-count, mean acc)
    comm_bytes: int
    episodes: int
    extras: dict = dataclasses.field(default_factory=dict)


def _layer_bytes() -> list[int]:
    return list(F.layer_sizes_bytes().values())


def run_regular_fl(h: FLHarness, t_rounds: int | None = None) -> FLResult:
    cfg = h.cfg
    T = t_rounds or cfg.t_rounds
    params, opt_state = h.params0, h.opt0
    weights = h.sizes
    history = []
    for t in range(T):
        params, opt_state, _ = h.local_train(params, opt_state,
                                             cfg.local_episodes)
        avg = h.aggregate(params, weights)
        params = h.broadcast(avg, h.n)
        if t % cfg.eval_every == 0 or t == T - 1:
            history.append(((t + 1) * cfg.local_episodes,
                            float(h.eval_all(params).mean())))
    per = h.eval_all(params)
    return FLResult("regular_fl", float(per.mean()), per, history,
                    CC.regular_fl_cost(_layer_bytes(), h.n, T),
                    T * cfg.local_episodes)


def run_fedper(h: FLHarness, t_rounds: int | None = None) -> FLResult:
    cfg = h.cfg
    T = t_rounds or cfg.t_rounds
    params, opt_state = h.params0, h.opt0
    mask = fd_cnn_mask(jax.tree.map(lambda x: x[0], params), cfg.base_layers)
    history = []
    for t in range(T):
        params, opt_state, _ = h.local_train(params, opt_state,
                                             cfg.local_episodes)
        avg = h.aggregate(params, h.sizes)
        bcast = h.broadcast(avg, h.n)
        # only base layers are replaced; personalized stay local (FedPer)
        params = jax.tree.map(
            lambda m, a, b: (m * a.astype(jnp.float32)
                             + (1 - m) * b.astype(jnp.float32)).astype(a.dtype),
            _stack_mask(mask, h.n), bcast, params)
        if t % cfg.eval_every == 0 or t == T - 1:
            history.append(((t + 1) * cfg.local_episodes,
                            float(h.eval_all(params).mean())))
    per = h.eval_all(params)
    return FLResult("fedper", float(per.mean()), per, history,
                    CC.fedper_cost(_layer_bytes(), h.n, T, cfg.base_layers),
                    T * cfg.local_episodes)


def run_individual(h: FLHarness, episodes: int | None = None) -> FLResult:
    cfg = h.cfg
    E = episodes or cfg.transfer_episodes
    params, opt_state = h.params0, h.opt0
    history = []
    chunk = max(cfg.eval_every * cfg.local_episodes, 8)
    done = 0
    while done < E:
        e = min(chunk, E - done)
        params, opt_state, _ = h.local_train(params, opt_state, e)
        done += e
        history.append((done, float(h.eval_all(params).mean())))
    per = h.eval_all(params)
    return FLResult("individual", float(per.mean()), per, history,
                    CC.individual_cost(), E)


def _stack_mask(mask, n):
    return jax.tree.map(lambda m: m, mask)   # scalars broadcast over stack


def run_cefl(h: FLHarness, t_rounds: int | None = None,
             k: int | None = None) -> FLResult:
    """Paper Algorithm 1 + §IV-B transfer session."""
    cfg = h.cfg
    T = t_rounds or cfg.t_rounds
    K = k or cfg.k_clusters
    params, opt_state = h.params0, h.opt0
    history = []

    # --- init: short local training, then similarity graph (Steps 1-2)
    params, opt_state, _ = h.local_train(params, opt_state,
                                         cfg.warmup_episodes)
    layer_trees = [params[name] for name in F.FD_CNN_LAYER_ORDER]
    S = np.asarray(similarity_graph(layer_flatten(params, layer_trees),
                                    use_kernel=cfg.use_kernel))
    labels = cluster_clients(S, K, cfg.seed)
    K = labels.max() + 1

    # --- Step 3: leader selection (eq. 5)
    clusters = [list(np.where(labels == c)[0]) for c in range(K)]
    leaders = [select_leader(S, m) for m in clusters]

    # --- FL among leaders with partial aggregation (Step 4, eq. 6-7)
    mask = fd_cnn_mask(jax.tree.map(lambda x: x[0], params), cfg.base_layers)
    lp = h.gather(params, leaders)
    lo = h.gather(opt_state, leaders)
    a_k = np.ones(K, np.float32) / K           # paper: a_k = 1/K
    episodes = cfg.warmup_episodes
    for t in range(T):
        lp, lo, _ = h.local_train(lp, lo, cfg.local_episodes, leaders)
        episodes += cfg.local_episodes
        avg = h.aggregate(lp, a_k)             # eq. 6 over base layers
        bcast = h.broadcast(avg, K)
        lp = jax.tree.map(                     # eq. 7: replace base only
            lambda m, a, b: (m * a.astype(jnp.float32)
                             + (1 - m) * b.astype(jnp.float32)).astype(a.dtype),
            _stack_mask(mask, K), bcast, lp)
        if t % cfg.eval_every == 0 or t == T - 1:
            accs = h.eval_all(lp)
            history.append((episodes, float(accs.mean())))

    # --- transfer session (eq. 8): members inherit leader's full model
    leader_of = np.array([leaders[labels[i]] for i in range(h.n)])
    src = jnp.asarray(leader_of)
    params = h.scatter(params, list(range(h.n)),
                       jax.tree.map(lambda x: x[src],
                                    h.scatter(params, leaders, lp)))
    # members fine-tune on their own data (leaders keep their FL model)
    member_ids = [i for i in range(h.n) if i not in set(leaders)]
    opt_state = jax.vmap(h.opt.init)(params)     # fresh fine-tune state
    fine = cfg.transfer_episodes
    chunk = max(cfg.eval_every * cfg.local_episodes, 8)
    done = 0
    while done < fine:
        e = min(chunk, fine - done)
        new_p, new_o, _ = h.local_train(params, opt_state, e)
        # only members adopt the fine-tuned weights
        mask_members = np.zeros(h.n, np.float32)
        mask_members[member_ids] = 1.0
        mm = jnp.asarray(mask_members)
        params = jax.tree.map(
            lambda a, b: (mm.reshape((-1,) + (1,) * (a.ndim - 1)) * a.astype(jnp.float32)
                          + (1 - mm.reshape((-1,) + (1,) * (a.ndim - 1))) * b.astype(jnp.float32)
                          ).astype(a.dtype), new_p, params)
        opt_state = new_o
        done += e
        history.append((episodes + done, float(h.eval_all(params).mean())))

    per = h.eval_all(params)
    ledger = CC.cefl_cost(_layer_bytes(), h.n, int(K), T, cfg.base_layers)
    return FLResult("cefl", float(per.mean()), per, history,
                    ledger.total, episodes + fine,
                    extras={"ledger": ledger, "labels": labels,
                            "leaders": leaders, "similarity": S})
