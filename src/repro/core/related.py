"""Related-work baselines the paper positions against (§II):

* **FedPAQ** [Reisizadeh et al. 2020]: partial participation + periodic
  averaging of QUANTIZED model updates — per round a fraction r of
  clients uploads b-bit-quantized deltas.
* **CMFL** [Luping et al. 2019]: clients upload only updates whose sign
  pattern agrees with the previous global update direction above a
  relevance threshold.

Both reuse the FLHarness (same vmapped local training, same data), so
Table-I-style comparisons are apples-to-apples with CEFL.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fl import FLHarness, FLResult, _layer_bytes


def _quantize_delta(delta, bits: int):
    """Uniform symmetric quantization of an update pytree."""
    def q(x):
        s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
        levels = 2 ** (bits - 1) - 1
        return jnp.round(x / s * levels) / levels * s
    return jax.tree.map(q, delta)


def run_fedpaq(h: FLHarness, t_rounds: int | None = None,
               participation: float = 0.5, bits: int = 8) -> FLResult:
    cfg = h.cfg
    T = t_rounds or cfg.t_rounds
    params, opt_state = h.params0, h.opt0
    rng = np.random.RandomState(cfg.seed + 11)
    history = []
    full = sum(_layer_bytes())
    comm = 0
    for t in range(T):
        k = max(1, int(participation * h.n))
        sel = rng.choice(h.n, size=k, replace=False)
        new_p, new_o, _ = h.local_train(params, opt_state, cfg.local_episodes)
        # only selected clients contribute; their deltas are quantized
        delta = jax.tree.map(lambda n, p: n - p, h.gather(new_p, sel),
                             h.gather(params, sel))
        qdelta = _quantize_delta(delta, bits)
        upd = jax.tree.map(lambda d: jnp.mean(d, axis=0), qdelta)
        avg = jax.tree.map(lambda g, d: (jnp.mean(g, 0) + d).astype(g.dtype),
                           params, upd)
        params = h.broadcast(avg, h.n)
        opt_state = new_o
        comm += k * full * bits // 32 + h.n * full   # quantized up, full down
        if t % cfg.eval_every == 0 or t == T - 1:
            history.append(((t + 1) * cfg.local_episodes,
                            float(h.eval_all(params).mean())))
    per = h.eval_all(params)
    return FLResult("fedpaq", float(per.mean()), per, history, comm,
                    T * cfg.local_episodes,
                    extras={"participation": participation, "bits": bits})


def run_cmfl(h: FLHarness, t_rounds: int | None = None,
             threshold: float = 0.5) -> FLResult:
    cfg = h.cfg
    T = t_rounds or cfg.t_rounds
    params, opt_state = h.params0, h.opt0
    history = []
    full = sum(_layer_bytes())
    comm = 0
    prev_dir = None
    uploaded_counts = []
    for t in range(T):
        new_p, new_o, _ = h.local_train(params, opt_state, cfg.local_episodes)
        prev_global = jax.tree.map(lambda x: np.asarray(x[0]), params)
        delta = jax.tree.map(lambda n, p: n - p, new_p, params)
        if prev_dir is None:
            keep = np.ones(h.n, bool)
        else:
            # per-client sign-agreement with the previous global direction
            agree = np.zeros(h.n)
            num = 0
            for d, g in zip(jax.tree.leaves(delta), jax.tree.leaves(prev_dir)):
                d2 = np.asarray(d).reshape(h.n, -1)
                g2 = np.sign(np.asarray(g).reshape(-1))[None, :]
                agree += (np.sign(d2) == g2).sum(axis=1)
                num += d2.shape[1]
            keep = (agree / num) >= threshold
            if not keep.any():
                keep[np.argmax(agree)] = True
        w = h.sizes * keep
        avg = h.aggregate(new_p, w)
        prev_dir = jax.tree.map(lambda a, g: np.asarray(a) - g,
                                avg, prev_global)
        params = h.broadcast(avg, h.n)
        opt_state = new_o
        comm += int(keep.sum()) * full + h.n * full
        uploaded_counts.append(int(keep.sum()))
        if t % cfg.eval_every == 0 or t == T - 1:
            history.append(((t + 1) * cfg.local_episodes,
                            float(h.eval_all(params).mean())))
    per = h.eval_all(params)
    return FLResult("cmfl", float(per.mean()), per, history, comm,
                    T * cfg.local_episodes,
                    extras={"uploaded_per_round": uploaded_counts})
