"""Step 2 of CEFL: Louvain community detection [Blondel et al. 2008]
on the weighted similarity graph, constrained to K clusters.

Pure-host implementation (the graph has N ≤ a few hundred vertices; the
device-side work is the similarity matrix, not the O(E) greedy sweep).

The paper specifies "the number of clusters needs to be specified
according to the demand" — vanilla Louvain maximizes modularity with a
free community count, so we post-process:
  * more than K communities → greedily merge the pair with the best
    (least-bad) modularity change until K remain;
  * fewer than K → split the loosest community by 2-medoid partition on
    the similarity rows until K remain.
"""
from __future__ import annotations

import numpy as np


def modularity(S: np.ndarray, labels: np.ndarray) -> float:
    """Weighted-graph modularity of a partition."""
    W = S.copy().astype(np.float64)
    np.fill_diagonal(W, 0.0)
    m2 = W.sum()
    if m2 <= 0:
        return 0.0
    k = W.sum(axis=1)
    q = 0.0
    for c in np.unique(labels):
        idx = labels == c
        q += W[np.ix_(idx, idx)].sum() / m2 - (k[idx].sum() / m2) ** 2
    return float(q)


def _louvain_pass(W: np.ndarray, rng: np.random.RandomState):
    """One level of local moves.  Returns community labels."""
    n = W.shape[0]
    m2 = W.sum()
    k = W.sum(axis=1)
    labels = np.arange(n)
    improved = True
    sweeps = 0
    while improved and sweeps < 50:
        improved = False
        sweeps += 1
        for i in rng.permutation(n):
            li = labels[i]
            # weights from i to each community
            neigh = {}
            for j in range(n):
                if j != i and W[i, j] != 0.0:
                    neigh[labels[j]] = neigh.get(labels[j], 0.0) + W[i, j]
            if not neigh:
                continue
            # degree sums per community (excluding i)
            best_c, best_gain = li, 0.0
            ki = k[i]
            sum_li = sum(k[j] for j in range(n)
                         if labels[j] == li and j != i)
            base = neigh.get(li, 0.0) - ki * sum_li / m2
            for c, w_ic in neigh.items():
                if c == li:
                    continue
                sum_c = sum(k[j] for j in range(n) if labels[j] == c)
                gain = (w_ic - ki * sum_c / m2) - base
                if gain > best_gain + 1e-12:
                    best_gain, best_c = gain, c
            if best_c != li:
                labels[i] = best_c
                improved = True
    # compact labels
    _, labels = np.unique(labels, return_inverse=True)
    return labels


def louvain(S: np.ndarray, seed: int = 0) -> np.ndarray:
    """Multi-level Louvain on similarity matrix S (diagonal ignored)."""
    W = np.asarray(S, np.float64).copy()
    np.fill_diagonal(W, 0.0)
    W = np.maximum(W, 0.0)          # similarity weights are ≥ 0 by eq. 4
    rng = np.random.RandomState(seed)
    n = W.shape[0]
    node_labels = np.arange(n)

    cur = W
    best_q = modularity(S, node_labels)
    best_labels = node_labels.copy()
    for _level in range(10):
        labels = _louvain_pass(cur, rng)
        trial = labels[node_labels]
        nc = labels.max() + 1
        q = modularity(S, trial)
        if q <= best_q + 1e-12:     # no modularity improvement → stop
            break
        best_q, best_labels = q, trial.copy()
        node_labels = trial
        if nc == cur.shape[0] or nc == 1:
            break
        # aggregate graph, KEEPING intra-community weight as self-loops
        # (dropping them makes every further merge look free)
        agg = np.zeros((nc, nc))
        for a in range(cur.shape[0]):
            for b in range(cur.shape[0]):
                agg[labels[a], labels[b]] += cur[a, b]
        cur = agg
    _, best_labels = np.unique(best_labels, return_inverse=True)
    return best_labels


def _merge_to_k(S: np.ndarray, labels: np.ndarray, k: int) -> np.ndarray:
    labels = labels.copy()
    while labels.max() + 1 > k:
        best = None
        ncur = labels.max() + 1
        for a in range(ncur):
            for b in range(a + 1, ncur):
                trial = labels.copy()
                trial[trial == b] = a
                trial[trial > b] -= 1
                q = modularity(S, trial)
                if best is None or q > best[0]:
                    best = (q, trial)
        labels = best[1]
    return labels


def _split_to_k(S: np.ndarray, labels: np.ndarray, k: int,
                rng: np.random.RandomState) -> np.ndarray:
    labels = labels.copy()
    while labels.max() + 1 < k:
        # split the largest community by 2-medoid on similarity rows
        sizes = np.bincount(labels)
        target = int(np.argmax(sizes))
        members = np.where(labels == target)[0]
        if len(members) < 2:
            break
        sub = S[np.ix_(members, members)]
        # farthest pair as medoids (least similar)
        a, b = np.unravel_index(np.argmin(sub + np.eye(len(members)) * sub.max()),
                                sub.shape)
        assign_b = sub[:, b] > sub[:, a]
        new_label = labels.max() + 1
        labels[members[assign_b]] = new_label
    return labels


def cluster_clients(S: np.ndarray, k: int, seed: int = 0) -> np.ndarray:
    """Louvain constrained to exactly K communities (CEFL Step 2)."""
    S = np.asarray(S, np.float64)
    n = S.shape[0]
    k = min(k, n)
    labels = louvain(S, seed)
    if labels.max() + 1 > k:
        labels = _merge_to_k(S, labels, k)
    elif labels.max() + 1 < k:
        labels = _split_to_k(S, labels, k, np.random.RandomState(seed))
    _, labels = np.unique(labels, return_inverse=True)
    return labels
