"""Step 1 of CEFL: the clients' similarity graph (paper eq. 3–4).

Given N clients' model weights, the similarity factor of clients i, j is

    d_ij = Σ_l ‖ω_i^l − ω_j^l‖₂            (eq. 3, per-layer Euclidean)
    S_ij = −d_ij + d_min + d_max            (eq. 4)

so large S = similar.  The O(N²·P) distance computation is the compute
hot-spot of the clustering step; it is evaluated through the Gram trick
‖a−b‖² = ‖a‖² + ‖b‖² − 2a·b so the N×P @ P×N product hits the MXU — the
Pallas kernel in ``repro.kernels.pairwise_dist`` implements exactly this
contraction tiled for VMEM; this module is the jnp reference path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def layer_flatten(stacked_params, layer_trees: list) -> list[jnp.ndarray]:
    """Per-layer (N, P_l) matrices from a client-stacked params pytree.

    ``layer_trees`` is a list of sub-pytrees (one per CEFL layer); each
    leaf has leading client dim N.
    """
    out = []
    for sub in layer_trees:
        leaves = [x.reshape(x.shape[0], -1) for x in jax.tree.leaves(sub)]
        out.append(jnp.concatenate(leaves, axis=1))
    return out


def pairwise_layer_distance(w: jnp.ndarray) -> jnp.ndarray:
    """(N, P) -> (N, N) Euclidean distances via the Gram trick."""
    w = w.astype(jnp.float32)
    sq = jnp.sum(w * w, axis=1)
    g = w @ w.T
    d2 = sq[:, None] + sq[None, :] - 2.0 * g
    d2 = jnp.maximum(d2, 0.0)
    return jnp.sqrt(d2)


def distance_matrix(layer_mats: list[jnp.ndarray], use_kernel: bool = False
                    ) -> jnp.ndarray:
    """Eq. 3: sum of per-layer Euclidean distances."""
    if use_kernel:
        from repro.kernels.ops import pairwise_dist
        mats = [pairwise_dist(w) for w in layer_mats]
    else:
        mats = [pairwise_layer_distance(w) for w in layer_mats]
    return jnp.sum(jnp.stack(mats), axis=0)


def similarity_from_distance(d: jnp.ndarray) -> jnp.ndarray:
    """Eq. 4: S_ij = −d_ij + d_min + d_max over off-diagonal entries."""
    n = d.shape[0]
    off = ~jnp.eye(n, dtype=bool)
    d_min = jnp.min(jnp.where(off, d, jnp.inf))
    d_max = jnp.max(jnp.where(off, d, -jnp.inf))
    s = -d + d_min + d_max
    return jnp.where(off, s, 0.0)


def similarity_graph(layer_mats: list[jnp.ndarray],
                     use_kernel: bool = False) -> jnp.ndarray:
    return similarity_from_distance(distance_matrix(layer_mats, use_kernel))


def select_leader(similarity: np.ndarray, members: list[int]) -> int:
    """Eq. 5: the member with max intra-cluster similarity sum."""
    if len(members) == 1:
        return members[0]
    sub = np.asarray(similarity)[np.ix_(members, members)]
    return members[int(np.argmax(sub.sum(axis=1)))]
