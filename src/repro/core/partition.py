"""Step 4 of CEFL: base / personalized parameter partition (eq. 6–7).

A partition is represented as a *mask pytree* matching the parameter
pytree: each leaf is a float (0./1.) array broadcastable against the
parameter leaf (scalar for unstacked leaves, (L,1,...) for scan-stacked
block leaves).  ``1.`` = base layer → participates in FL aggregation;
``0.`` = personalized → stays local.

Two predicates:
  * ``prefix``     — the paper's: the first B layers are base (plus the
                     input embedding / frontend); final norm + LM head
                     are personalized.
  * ``non_expert`` — MoE refinement (DESIGN.md §4): everything except
                     expert weights is base; experts are personalized.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.fd_cnn import FD_CNN_LAYER_ORDER

EXPERT_KEYS = ("wi", "wg", "wo")        # under a "moe" subtree


def _ones_like_mask(leaf):
    return jnp.ones((1,) * 0, jnp.float32)  # scalar 1.


def fd_cnn_mask(params, base_layers: int):
    """Prefix-B mask over FD-CNN's named layer order.

    Masks are NUMPY trees (trace-time constants): the sharded CEFL sync
    makes static skip decisions from them inside jit."""
    base = set(FD_CNN_LAYER_ORDER[:base_layers])
    return {name: jax.tree.map(lambda _: np.float32(1.0 if name in base else 0.0),
                               sub)
            for name, sub in params.items()}


def transformer_mask(cfg: ModelConfig, params):
    """Mask pytree for a zoo architecture (stacked or per-layer blocks)."""
    B = cfg.base_layers if cfg.base_layers is not None else cfg.n_layers // 2
    mask = {}
    for key, sub in params.items():
        if key == "blocks":
            mask[key] = _blocks_mask(cfg, sub, B)
        elif key in ("embed", "frontend_proj", "img_proj"):
            mask[key] = jax.tree.map(lambda _: np.float32(1.0), sub)
        elif key == "shared_attn":     # zamba2 shared block: global → base
            mask[key] = jax.tree.map(lambda _: np.float32(1.0), sub)
        else:                          # final_norm, head → personalized
            mask[key] = jax.tree.map(lambda _: np.float32(0.0), sub)
    return mask


def _blocks_mask(cfg: ModelConfig, blocks, B: int):
    if isinstance(blocks, list):       # per-layer blocks (xlstm / zamba2)
        def layer_mask(i, sub):
            v = np.float32(1.0 if i < B else 0.0)
            return jax.tree.map(lambda _: v, sub)
        return [layer_mask(i, sub) for i, sub in enumerate(blocks)]

    # scan-stacked: leaves have leading L dim → per-layer (L,1,...) masks
    L = cfg.n_layers
    prefix = (np.arange(L) < B).astype(np.float32)

    def leaf_mask(path, leaf):
        keys = [getattr(p, "key", "") for p in path]
        if cfg.base_predicate == "non_expert" and "moe" in keys and \
                keys[-1] in EXPERT_KEYS:
            return np.zeros((L,) + (1,) * (leaf.ndim - 1), np.float32)
        vec = prefix if cfg.base_predicate == "prefix" else \
            np.ones((L,), np.float32)
        return vec.reshape((L,) + (1,) * (leaf.ndim - 1))

    return jax.tree_util.tree_map_with_path(leaf_mask, blocks)


def param_mask(cfg: ModelConfig, params):
    if cfg.arch_type == "cnn":
        return fd_cnn_mask(params, cfg.base_layers or 2)
    return transformer_mask(cfg, params)


def masked_interpolate(mask, new, old):
    """new where mask==1 else old (eq. 7 broadcast over stacked layers)."""
    return jax.tree.map(
        lambda m, a, b: (m * a.astype(jnp.float32)
                         + (1.0 - m) * b.astype(jnp.float32)).astype(a.dtype),
        mask, new, old)


def mask_fraction(mask, params) -> float:
    """Fraction of parameter *bytes* covered by the base mask (→ eq. 9)."""
    tot, base = 0.0, 0.0
    for m, p in zip(jax.tree.leaves(mask), jax.tree.leaves(params)):
        n = float(np.prod(p.shape))
        tot += n
        base += float(np.mean(np.asarray(m, np.float32))) * n
    return base / tot
