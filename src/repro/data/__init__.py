from repro.data.mobiact import (ACTIVITY_CLASSES, SyntheticMobiAct,
                                make_client_datasets, windows_to_bitmaps)
from repro.data.lm import synthetic_lm_batch, synthetic_lm_stream
