"""Synthetic token / multimodal batch generators for the LM backbones.

Markov-chain token streams give the models non-trivial structure to fit
(loss decreases measurably in the end-to-end example drivers) without an
offline corpus.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig


def _markov_tokens(rng: np.random.RandomState, batch: int, seq: int,
                   vocab: int, order_states: int = 64):
    state = rng.randint(0, order_states, size=batch)
    # each hidden state prefers a band of the vocabulary
    centers = rng.randint(0, vocab, size=order_states)
    toks = np.zeros((batch, seq), np.int32)
    for t in range(seq):
        jump = rng.rand(batch) < 0.1
        state = np.where(jump, rng.randint(0, order_states, size=batch), state)
        band = (centers[state]
                + rng.randint(-vocab // 16 - 1, vocab // 16 + 1, size=batch))
        toks[:, t] = np.clip(band, 0, vocab - 1)
    return toks


def synthetic_lm_batch(cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
    """One training batch matching ``input_specs`` for any arch."""
    rng = np.random.RandomState(seed)
    if cfg.arch_type == "audio":
        return {
            "frames": rng.randn(batch, seq, cfg.frontend_dim).astype(np.float32),
            "labels": rng.randint(0, cfg.vocab, (batch, seq)).astype(np.int32),
        }
    if cfg.arch_type == "vlm":
        s_text = seq - cfg.n_img_tokens
        toks = _markov_tokens(rng, batch, s_text, cfg.vocab)
        return {
            "tokens": toks,
            "img_emb": rng.randn(batch, cfg.n_img_tokens,
                                 cfg.frontend_dim).astype(np.float32),
            "labels": toks,
        }
    toks = _markov_tokens(rng, batch, seq, cfg.vocab)
    return {"tokens": toks, "labels": toks}


def synthetic_lm_stream(cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
    step = 0
    while True:
        yield synthetic_lm_batch(cfg, batch, seq, seed + step)
        step += 1
