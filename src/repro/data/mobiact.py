"""Synthetic MobiAct-like dataset + the paper's preprocessing (§V-A).

MobiAct itself (67 subjects, smartphone IMU) is not redistributable
offline, so we generate a *synthetic* corpus with the same interface:
per-subject 3-axial acceleration + angular-velocity traces for the
paper's 8 activity classes, with per-class recording durations mirroring
the paper's description (falls ≈ 10 s, daily activities up to minutes).

Preprocessing follows [He et al. 2019] as the paper does: a sliding
window with class-adapted slide interval (eq. 10)
    I_type = I_0 · t_type / t_0
captures 20-sample windows of the 6 signal channels, converted to a
20×20×3 RGB bitmap (acceleration xyz → one pixel row block's RGB,
angular velocity xyz → another; normalized to [0,1]).

Classes are separable but noisy: each class has a distinct frequency/
amplitude signature per channel, plus per-subject gain/phase variation —
enough structure that FD-CNN reaches high accuracy with data, and small/
unbalanced clients underperform (the paper's Fig. 5 regime).
"""
from __future__ import annotations

import dataclasses

import numpy as np

ACTIVITY_CLASSES = (
    "forward_lying", "front_knees_lying", "sideward_lying", "back_sitting_chair",
    "sit_chair", "car_step_in", "car_step_out", "daily_activity",
)
N_CLASSES = len(ACTIVITY_CLASSES)

SAMPLE_HZ = 20          # IMU sampling rate used for the bitmaps
T0_SECONDS = 10.0       # reference duration t_0 (falls are 10 s)
I0 = 40                 # reference slide interval I_0 (paper §V-A)
WINDOW = 20             # samples per window → 20×20 bitmap rows

# per-class recorded duration t_type (seconds) — falls 10 s, fall-like
# dozens of seconds, daily activities minutes (paper: up to 10 min)
CLASS_DURATION_S = {
    "forward_lying": 10.0, "front_knees_lying": 10.0,
    "sideward_lying": 10.0, "back_sitting_chair": 10.0,
    "sit_chair": 30.0, "car_step_in": 30.0, "car_step_out": 30.0,
    "daily_activity": 600.0,
}


def slide_interval(class_name: str) -> int:
    """Eq. 10: I_type = I_0 · t_type / t_0 (keeps classes balanced)."""
    return max(1, int(round(I0 * CLASS_DURATION_S[class_name] / T0_SECONDS)))


# ------------------------------------------------------------ raw signals

# class signatures: (base freq Hz, amp, impact spike) per class for the 6
# channels (acc xyz, gyro xyz)
_RNG_SIG = np.random.RandomState(1234)
_CLASS_FREQ = 0.5 + 3.0 * _RNG_SIG.rand(N_CLASSES, 6)
_CLASS_AMP = 0.5 + 1.5 * _RNG_SIG.rand(N_CLASSES, 6)
_CLASS_PHASE = 2 * np.pi * _RNG_SIG.rand(N_CLASSES, 6)


def synth_signal(class_id: int, subject_rng: np.random.RandomState,
                 duration_s: float) -> np.ndarray:
    """(T, 6) synthetic IMU trace for one recording."""
    n = int(duration_s * SAMPLE_HZ)
    t = np.arange(n) / SAMPLE_HZ
    gain = 1.0 + 0.25 * subject_rng.randn(6)
    phase = 0.3 * subject_rng.randn(6)
    sig = np.stack([
        gain[c] * _CLASS_AMP[class_id, c]
        * np.sin(2 * np.pi * _CLASS_FREQ[class_id, c] * t
                 + _CLASS_PHASE[class_id, c] + phase[c])
        for c in range(6)], axis=1)
    if class_id < 4:  # falls: impact spike midway
        mid = n // 2
        spike = np.exp(-0.5 * ((np.arange(n) - mid) / (0.1 * SAMPLE_HZ)) ** 2)
        sig[:, :3] += 3.0 * spike[:, None]
    sig += 0.35 * subject_rng.randn(n, 6)
    return sig.astype(np.float32)


def windows_to_bitmaps(sig: np.ndarray, interval: int) -> np.ndarray:
    """Sliding windows → (N, 20, 20, 3) bitmaps.

    Each 20-sample window of the 6 channels becomes a 20×20 RGB image:
    rows 0-9 tile acceleration xyz as RGB, rows 10-19 angular velocity
    xyz, column dimension is time; values min-max normalized to [0,1].
    """
    T = sig.shape[0]
    starts = range(0, T - WINDOW + 1, interval)
    out = []
    for s in starts:
        w = sig[s:s + WINDOW]                        # (20, 6)
        lo, hi = w.min(), w.max()
        w = (w - lo) / (hi - lo + 1e-6)
        acc = np.repeat(w[None, :, :3], 10, axis=0)   # (10, 20, 3)
        gyr = np.repeat(w[None, :, 3:], 10, axis=0)
        out.append(np.concatenate([acc, gyr], axis=0))
    return np.asarray(out, np.float32) if out else np.zeros((0, 20, 20, 3), np.float32)


# ------------------------------------------------------------- federated


@dataclasses.dataclass
class ClientDataset:
    x: np.ndarray            # (N, 20, 20, 3)
    y: np.ndarray            # (N,) int
    subject: int

    def __len__(self):
        return len(self.y)

    def batches(self, batch_size: int, rng: np.random.RandomState):
        idx = rng.permutation(len(self.y))
        for s in range(0, len(idx) - batch_size + 1, batch_size):
            sel = idx[s:s + batch_size]
            yield {"x": self.x[sel], "y": self.y[sel]}


@dataclasses.dataclass
class SyntheticMobiAct:
    clients: list[ClientDataset]
    test_x: np.ndarray
    test_y: np.ndarray


def make_client_datasets(n_clients: int = 67, seed: int = 0,
                         heterogeneity: float = 0.5,
                         scale: float = 1.0) -> SyntheticMobiAct:
    """Build the federated corpus.

    ``heterogeneity`` ∈ [0,1]: 0 → every client has all classes evenly;
    1 → strongly skewed Dirichlet class mixes (small/unbalanced clients,
    the paper's Fig. 5 regime).  ``scale`` scales per-client data volume.
    """
    master = np.random.RandomState(seed)
    clients = []
    for s in range(n_clients):
        rng = np.random.RandomState(seed * 1000 + s + 1)
        alpha = np.full(N_CLASSES, max(1e-2, 2.0 * (1 - heterogeneity) + 0.1))
        mix = rng.dirichlet(alpha)
        # per-client volume varies ~5x (paper: 101 .. 831 samples)
        volume = scale * (0.3 + 1.4 * rng.rand())
        xs, ys = [], []
        for c, cname in enumerate(ACTIVITY_CLASSES):
            n_rec = max(0, int(round(6 * mix[c] * volume * N_CLASSES / 2)))
            for _ in range(n_rec):
                sig = synth_signal(c, rng, CLASS_DURATION_S[cname])
                bm = windows_to_bitmaps(sig, slide_interval(cname))
                xs.append(bm)
                ys.append(np.full(len(bm), c, np.int32))
        x = (np.concatenate(xs) if xs else np.zeros((0, 20, 20, 3), np.float32))
        y = (np.concatenate(ys) if ys else np.zeros((0,), np.int32))
        while len(y) < 8:   # guarantee a trainable client
            sig = synth_signal(7, rng, CLASS_DURATION_S["daily_activity"])
            bm = windows_to_bitmaps(sig, slide_interval("daily_activity") // 2)
            x = np.concatenate([x, bm])
            y = np.concatenate([y, np.full(len(bm), 7, np.int32)])
        clients.append(ClientDataset(x, y, s))

    # common test set: balanced, held-out subjects
    xs, ys = [], []
    for c, cname in enumerate(ACTIVITY_CLASSES):
        rng = np.random.RandomState(99_000 + c)
        for _ in range(4):
            sig = synth_signal(c, rng, min(CLASS_DURATION_S[cname], 60.0))
            bm = windows_to_bitmaps(sig, max(1, slide_interval(cname) // 4))
            xs.append(bm)
            ys.append(np.full(len(bm), c, np.int32))
    return SyntheticMobiAct(clients, np.concatenate(xs), np.concatenate(ys))
