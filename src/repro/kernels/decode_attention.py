"""Pallas TPU kernel: single-query (decode) attention over a KV cache.

The decode hot-spot is bandwidth: one new query attends to a W-entry
rolling-buffer cache, so the kernel's job is to stream K/V through VMEM
once, carrying the online-softmax running max / normalizer / accumulator
in scratch — per-(batch·head) grid cells over key blocks.

Rolling-buffer semantics are passed in as a precomputed (W,) validity
mask (the ops wrapper derives it from ``pos``): slots not yet written
this wrap are masked, matching ``layers.decode_attention``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BK = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_blocks: int, scale: float):
    kb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (d,)
    k = k_ref[0].astype(jnp.float32)                   # (bk, d)
    v = v_ref[0].astype(jnp.float32)                   # (bk, d)
    mask = mask_ref[...] > 0.5                         # (bk,)

    s = jnp.sum(k * q[None, :], axis=1)                # (bk,)
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)       # (bk,)
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + jnp.sum(p[:, None] * v, axis=0)
    m_ref[0] = m_new

    @pl.when(kb == n_blocks - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[0], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                            valid: jax.Array, *, bk: int = DEFAULT_BK,
                            interpret: bool = True) -> jax.Array:
    """q: (BH, 1, d); k/v: (BH, W, d); valid: (W,) f32 -> (BH, 1, d)."""
    bh, w, d = k.shape
    assert w % bk == 0, (w, bk)
    grid = (bh, w // bk)
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(_kernel, n_blocks=w // bk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda h, j: (h, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, j: (h, j, 0)),
            pl.BlockSpec((bk,), lambda h, j: (j,)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda h, j: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((d,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, valid)
