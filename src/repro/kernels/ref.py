"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def pairwise_dist_ref(w: jax.Array) -> jax.Array:
    """(N, P) -> (N, N) Euclidean distances."""
    w = w.astype(jnp.float32)
    sq = jnp.sum(w * w, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (w @ w.T)
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def partial_agg_ref(w: jax.Array, a: jax.Array, gamma: jax.Array,
                    self_idx: int, bp: int) -> jax.Array:
    """(K, P) stack -> (P,) masked aggregate (eq. 6-7)."""
    w = w.astype(jnp.float32)
    agg = jnp.sum(w * a.astype(jnp.float32)[:, None], axis=0)
    g = jnp.repeat(gamma.astype(jnp.float32), bp)
    return g * agg + (1.0 - g) * w[self_idx]


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """(BH, Sq, d) x (BH, Sk, d) -> (BH, Sq, d), exact softmax."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (d ** 0.5)
    sq, sk = q.shape[1], k.shape[1]
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= (qi - kj) < window
        if not causal:
            mask &= (kj - qi) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask.any(-1)[None, :, None], p, 0.0)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k, v, pos):
    """Oracle for the decode kernel: masked softmax over the cache.
    q: (B,1,H,d); k/v: (B,W,KV,d); pos: () -> (B,1,H,d)."""
    b, _, h, d = q.shape
    w, kv = k.shape[1], k.shape[2]
    g = h // kv
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bwhd->bhqw", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / (d ** 0.5)
    slot = jnp.mod(pos, w)
    idx = jnp.arange(w)
    valid = (idx <= slot) | (pos >= w)
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqw,bwhd->bqhd", p,
                      vr.astype(jnp.float32)).astype(q.dtype)
