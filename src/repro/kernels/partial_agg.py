"""Pallas TPU kernel: fused partial-layer FL aggregation (CEFL Step 4).

Computes   out = γ · (Σ_k a_k · W[k]) + (1 − γ) · W[self]      (eq. 6–7)

over a client-stacked flat weight matrix W (K, P), with per-chunk base
mask γ ∈ {0,1} (1 → aggregate, 0 → keep own weights).  One HBM pass:
the stack tile is read once, the weighted reduction over K runs on the
VPU, and the masked select is fused — replacing the mask-multiply-
broadcast-add chain the jnp reference builds (3× the HBM traffic).

Grid: (P / bp,); block (K, bp).  K (≤ a few hundred clients) stays
resident; bp is lane-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BP = 1024


def _kernel(w_ref, a_ref, g_ref, o_ref, *, self_idx: int):
    w = w_ref[...].astype(jnp.float32)          # (K, bp)
    a = a_ref[...].astype(jnp.float32)          # (K,)
    gamma = g_ref[0]                            # () mask for this chunk
    agg = jnp.sum(w * a[:, None], axis=0)       # (bp,)
    own = w[self_idx]
    o_ref[...] = gamma * agg + (1.0 - gamma) * own


def partial_agg_pallas(w: jax.Array, a: jax.Array, gamma: jax.Array,
                       self_idx: int, *, bp: int = DEFAULT_BP,
                       interpret: bool = True) -> jax.Array:
    """w: (K, P) stack, a: (K,) weights, gamma: (P/bp,) per-chunk mask.

    Returns (P,) f32 — client ``self_idx``'s post-round weights.
    """
    k, p = w.shape
    assert p % bp == 0, (p, bp)
    grid = (p // bp,)
    return pl.pallas_call(
        functools.partial(_kernel, self_idx=self_idx),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, bp), lambda i: (0, i)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), jnp.float32),
        interpret=interpret,
    )(w, a, gamma)
