"""Jit'd public wrappers around the Pallas kernels: shape padding,
GQA head expansion, and dtype plumbing.  ``interpret=True`` (default
here) runs the kernel body on CPU for validation; on a real TPU deploy
pass ``interpret=False``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as DA
from repro.kernels import flash_attention as FA
from repro.kernels import pairwise_dist as PD
from repro.kernels import partial_agg as PA


def _pad_to(x, mult, axis):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("bn", "bp", "interpret"))
def pairwise_dist(w: jax.Array, *, bn: int = PD.DEFAULT_BN,
                  bp: int = PD.DEFAULT_BP, interpret: bool = True):
    """(N, P) -> (N, N) f32 pairwise Euclidean distances (CEFL eq. 3)."""
    n = w.shape[0]
    wp = _pad_to(_pad_to(w, bn, 0), bp, 1)
    d = PD.pairwise_dist_pallas(wp, bn=bn, bp=bp, interpret=interpret)
    return d[:n, :n]


@functools.partial(jax.jit, static_argnames=("self_idx", "bp", "interpret"))
def partial_agg(w: jax.Array, a: jax.Array, gamma_per_chunk: jax.Array,
                self_idx: int, *, bp: int = PA.DEFAULT_BP,
                interpret: bool = True):
    """(K, P), (K,), (P/bp,) -> (P,) fused masked aggregation (eq. 6-7)."""
    p = w.shape[1]
    wp = _pad_to(w, bp, 1)
    npad_chunks = wp.shape[1] // bp - gamma_per_chunk.shape[0]
    g = jnp.concatenate([gamma_per_chunk.astype(jnp.float32),
                         jnp.zeros((npad_chunks,), jnp.float32)])
    out = PA.partial_agg_pallas(wp, a, g, self_idx, bp=bp,
                                interpret=interpret)
    return out[:p]


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    bq: int = FA.DEFAULT_BQ, bk: int = FA.DEFAULT_BK,
                    interpret: bool = True):
    """GQA flash attention.  q: (B, Sq, H, d); k/v: (B, Sk, KV, d).

    Returns (B, Sq, H, d).  Handles padding to block multiples and the
    H/KV grouped expansion (keys are gathered per group, not repeated in
    HBM — the wrapper reshapes views only).
    """
    b, sq, h, dd = q.shape
    kv = k.shape[2]
    g = h // kv
    # (B, S, H, d) -> (B*H, S, d) with kv shared across each group of g
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, sq, dd)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, -1, dd)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, -1, dd)
    sqp = qh.shape[1]
    qp = _pad_to(qh, bq, 1)
    kp = _pad_to(kh, bk, 1)
    vp = _pad_to(vh, bk, 1)
    out = FA.flash_attention_pallas(qp, kp, vp, causal=causal, window=window,
                                    bq=bq, bk=bk, sk_valid=kh.shape[1],
                                    interpret=interpret)
    out = out[:, :sq]
    return out.reshape(b, h, sq, dd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, *, bk: int = DA.DEFAULT_BK,
                     interpret: bool = True):
    """Single-token GQA decode attention over a rolling-buffer cache.

    q: (B, 1, H, d); k/v: (B, W, KV, d); pos: () int32 context length.
    Returns (B, 1, H, d).  Validity follows layers.decode_attention:
    slot = pos % W is the just-written entry; earlier slots this wrap or
    a fully wrapped buffer are valid.
    """
    b, _, h, d = q.shape
    w, kv = k.shape[1], k.shape[2]
    g = h // kv
    slot = jnp.mod(pos, w)
    idx = jnp.arange(w)
    valid = ((idx <= slot) | (pos >= w)).astype(jnp.float32)

    qh = q.transpose(0, 2, 1, 3).reshape(b * h, 1, d)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, w, d)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, w, d)
    pad = (-w) % bk
    if pad:
        kh = jnp.pad(kh, ((0, 0), (0, pad), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pad), (0, 0)))
        valid = jnp.pad(valid, (0, pad))
    out = DA.decode_attention_pallas(qh, kh, vh, valid, bk=bk,
                                     interpret=interpret)
    return out.reshape(b, h, 1, d).transpose(0, 2, 1, 3)
