"""Pallas TPU kernel: pairwise Euclidean distance matrix (CEFL Step 1).

Computes D[i,j] = ‖w_i − w_j‖₂ for N client weight vectors of width P
via the Gram trick  d²(i,j) = Σ_chunk (‖x_i‖² + ‖x_j‖² − 2·x_i·x_j),
so the dominant work is an MXU matmul per (i-tile, j-tile, P-chunk).

Tiling: grid (N/bn, N/bn, P/bp); the P-chunk axis is the innermost
(sequential) grid dim and accumulates into an f32 VMEM scratch tile;
the final chunk writes sqrt(max(acc, 0)).  Block sizes are multiples of
the 128-lane MXU width.  Inputs are padded by ``ops.pairwise_dist`` so
callers never see the tile granularity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BN = 128     # client-tile (MXU-aligned)
DEFAULT_BP = 512     # weight-chunk


def _kernel(x_ref, y_ref, o_ref, acc_ref, *, n_chunks: int):
    pk = pl.program_id(2)
    # program_id must be read in the main body, not inside a pl.when
    # closure (the interpret-mode lowering can't substitute it there)
    pi = pl.program_id(0)
    pj = pl.program_id(1)

    @pl.when(pk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)          # (bn, bp)
    y = y_ref[...].astype(jnp.float32)          # (bn, bp)
    g = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    sx = jnp.sum(x * x, axis=1, keepdims=True)          # (bn, 1)
    sy = jnp.sum(y * y, axis=1, keepdims=True).T        # (1, bn)
    acc_ref[...] += sx + sy - 2.0 * g

    @pl.when(pk == n_chunks - 1)
    def _done():
        d = jnp.sqrt(jnp.maximum(acc_ref[...], 0.0))
        # exact-zero self-distance: the Gram trick's fp32 cancellation
        # noise otherwise leaves ~1e-3 junk on the diagonal
        eye = (jax.lax.broadcasted_iota(jnp.int32, d.shape, 0)
               == jax.lax.broadcasted_iota(jnp.int32, d.shape, 1))
        o_ref[...] = jnp.where((pi == pj) & eye, 0.0, d)


def pairwise_dist_pallas(w: jax.Array, *, bn: int = DEFAULT_BN,
                         bp: int = DEFAULT_BP,
                         interpret: bool = True) -> jax.Array:
    """w: (N, P) padded to multiples of (bn, bp) -> (N, N) f32 distances.

    Zero-padding P is safe (adds 0 to every squared distance); padding N
    adds rows whose distances are sliced off by the wrapper.
    """
    n, p = w.shape
    assert n % bn == 0 and p % bp == 0, (n, p, bn, bp)
    n_chunks = p // bp
    grid = (n // bn, n // bn, n_chunks)
    return pl.pallas_call(
        functools.partial(_kernel, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bp), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bp), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, bn), jnp.float32)],
        interpret=interpret,
    )(w, w)
