"""Pallas TPU kernel: flash attention forward (causal / bidirectional /
sliding-window), the backbone's compute hot-spot.

Online-softmax over key blocks: for each (batch·head, q-block) the
kernel iterates key blocks in the innermost (sequential) grid dim,
carrying the running max m, normalizer l, and un-normalized output
accumulator in VMEM scratch; the final key block writes acc / l.

Blocks default to (128, 128) — MXU-aligned — and the q/k tiles plus the
(bq, bk) score tile bound the VMEM working set independent of sequence
length; this is the TPU-native replacement for the quadratic S×S score
materialization (and for the CUDA shared-memory variant the GPU papers
tile for SMs).

GQA layout: inputs are (B·H, S, d); grouped heads are expanded by the
ops wrapper via an index map (no materialized repeat).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_kblocks: int, bq: int, bk: int, causal: bool,
            window: int | None, scale: float, sk_valid: int):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0].astype(jnp.float32)                  # (bk, d)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)
    qi = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kj = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kj < sk_valid           # exclude zero-padded key rows
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= (qi - kj) < window
        if not causal:
            mask &= (kj - qi) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    # fully-masked rows: keep everything at zero instead of exp(-inf-(-inf))
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == n_kblocks - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           sk_valid: int | None = None,
                           interpret: bool = True) -> jax.Array:
    """q: (BH, Sq, d), k/v: (BH, Sk, d) -> (BH, Sq, d).

    Sq % bq == 0 and Sk % bk == 0 (ops wrapper pads); ``sk_valid`` marks
    the number of real (non-padded) key rows.
    """
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % bq == 0 and sk % bk == 0, (sq, sk, bq, bk)
    grid = (bh, sq // bq, sk // bk)
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(_kernel, n_kblocks=sk // bk, bq=bq, bk=bk,
                          causal=causal, window=window, scale=scale,
                          sk_valid=sk_valid if sk_valid is not None else sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
