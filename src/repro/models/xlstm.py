"""xLSTM blocks: chunked mLSTM (matrix memory) and recurrent sLSTM.

TPU adaptation notes (recorded per DESIGN.md):
  * mLSTM's matrix-memory recurrence C_t = f_t·C_{t-1} + i_t·v_t k_tᵀ is
    the same algebraic form as SSD, so it is computed with the same
    chunked scheme — quadratic-in-chunk einsums on the MXU plus a
    between-chunk `lax.scan` — rather than a CUDA fused recurrent kernel.
  * We use a sigmoid forget gate (log-sigmoid cumulative decay) and a
    clipped exponential input gate instead of the paper's running-max
    stabilizer; the normalizer n_t is carried as an extra value column.
  * sLSTM has a true nonlinear hidden-to-hidden recurrence and cannot be
    parallelized over time; it runs as a `lax.scan` over timesteps with
    block-diagonal (per-head) recurrent weights, as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import ParamSpec

I_GATE_CAP = 10.0


def _mdims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    H = cfg.n_heads
    P = d_inner // H          # value dim per head
    N = P // 2                # query/key dim per head
    return d_inner, H, P, N


def mlstm_params(cfg: ModelConfig):
    d = cfg.d_model
    d_inner, H, P, N = _mdims(cfg)
    return {
        "in_proj": ParamSpec((d, 2 * d_inner), ("embed", "mlp")),   # x-branch, z-gate
        "wq": ParamSpec((d_inner, H, N), ("mlp", "heads", "head_dim")),
        "wk": ParamSpec((d_inner, H, N), ("mlp", "heads", "head_dim")),
        "wv": ParamSpec((d_inner, H, P), ("mlp", "heads", "head_dim")),
        "wif": ParamSpec((d_inner, 2 * H), ("mlp", "heads"), "normal", scale=0.01),
        "b_if": ParamSpec((2 * H,), ("heads",), "zeros"),
        "out_proj": ParamSpec((d_inner, d), ("mlp", "embed")),
    }


def _chunked_linear(q, k, v, log_decay, gate_in, chunk, state=None):
    """y_t = q_t · (Σ_{s≤t} exp(cum_t - cum_s)·gate_s·k_s v_sᵀ).

    q,k: (B,L,H,N)  v: (B,L,H,P)  log_decay,gate_in: (B,L,H) (f32).
    Returns (y (B,L,H,P) f32, final_state (B,H,N,P) f32).
    """
    B, L, H, N = q.shape
    P = v.shape[-1]
    Q = min(chunk, L)
    Lp = -(-L // Q) * Q
    if Lp != L:  # pad: gate 0 + decay 1 on padded steps leaves state intact
        pad = ((0, 0), (0, Lp - L), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, pad) for t in (q, k, v))
        log_decay = jnp.pad(log_decay, ((0, 0), (0, Lp - L), (0, 0)))
        gate_in = jnp.pad(gate_in, ((0, 0), (0, Lp - L), (0, 0)))
    nc = Lp // Q
    qc = q.reshape(B, nc, Q, H, N).astype(jnp.float32)
    kc = k.reshape(B, nc, Q, H, N).astype(jnp.float32)
    vc = v.reshape(B, nc, Q, H, P).astype(jnp.float32)
    gc = gate_in.reshape(B, nc, Q, H)
    cum = jnp.cumsum(log_decay.reshape(B, nc, Q, H), axis=2)

    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    mask = jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :]
    decay_m = jnp.where(mask[None, None, :, :, None], jnp.exp(rel), 0.0)
    qk = jnp.einsum("bnthk,bnshk->bntsh", qc, kc)
    M = decay_m * qk * gc[:, :, None, :, :]
    y_intra = jnp.einsum("bntsh,bnshp->bnthp", M, vc)

    tail = jnp.exp(cum[:, :, -1:, :] - cum) * gc
    chunk_state = jnp.einsum("bnsh,bnshk,bnshp->bnhkp", tail, kc, vc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])
    init = (jnp.zeros((B, H, N, P), jnp.float32) if state is None
            else state.astype(jnp.float32))

    def scan_fn(s, inp):
        cd, cs = inp
        return s * cd[:, :, None, None] + cs, s

    final, entry = jax.lax.scan(
        scan_fn, init,
        (chunk_decay.transpose(1, 0, 2), chunk_state.transpose(1, 0, 2, 3, 4)))
    entry = entry.transpose(1, 0, 2, 3, 4)
    y_inter = jnp.einsum("bnthk,bnth,bnhkp->bnthp", qc, jnp.exp(cum), entry)
    return (y_intra + y_inter).reshape(B, Lp, H, P)[:, :L], final


def apply_mlstm(cfg: ModelConfig, p, x, state=None):
    B, L, d = x.shape
    d_inner, H, P, N = _mdims(cfg)
    dt_ = x.dtype
    xb, z = jnp.split(jnp.einsum("bld,de->ble", x, p["in_proj"].astype(dt_)), 2, -1)
    q = jnp.einsum("ble,ehn->blhn", xb, p["wq"].astype(dt_)) / jnp.sqrt(N).astype(dt_)
    k = jnp.einsum("ble,ehn->blhn", xb, p["wk"].astype(dt_))
    v = jnp.einsum("ble,ehp->blhp", xb, p["wv"].astype(dt_))
    if_ = (jnp.einsum("ble,eh->blh", xb, p["wif"].astype(dt_))
           + p["b_if"].astype(dt_)).astype(jnp.float32)
    i_raw, f_raw = jnp.split(if_, 2, -1)
    log_decay = jax.nn.log_sigmoid(f_raw)
    gate_in = jnp.exp(jnp.minimum(i_raw, I_GATE_CAP))

    # carry the normalizer as an extra value column
    v_aug = jnp.concatenate([v.astype(jnp.float32),
                             jnp.ones(v.shape[:-1] + (1,), jnp.float32)], -1)
    y_aug, final = _chunked_linear(q, k, v_aug, log_decay, gate_in,
                                   cfg.ssm_chunk or 256, state)
    y, n = y_aug[..., :P], y_aug[..., P:]
    y = y / jnp.maximum(jnp.abs(n), 1.0)
    y = y.reshape(B, L, d_inner).astype(dt_) * jax.nn.silu(z)
    return jnp.einsum("ble,ed->bld", y, p["out_proj"].astype(dt_)), final


def decode_mlstm(cfg: ModelConfig, p, x, state):
    """One-step decode. state: (B,H,N,P+1) f32."""
    B = x.shape[0]
    d_inner, H, P, N = _mdims(cfg)
    dt_ = x.dtype
    xb, z = jnp.split(jnp.einsum("bld,de->ble", x, p["in_proj"].astype(dt_)), 2, -1)
    q = jnp.einsum("ble,ehn->blhn", xb, p["wq"].astype(dt_))[:, 0] / jnp.sqrt(N).astype(dt_)
    k = jnp.einsum("ble,ehn->blhn", xb, p["wk"].astype(dt_))[:, 0]
    v = jnp.einsum("ble,ehp->blhp", xb, p["wv"].astype(dt_))[:, 0]
    if_ = (jnp.einsum("ble,eh->blh", xb, p["wif"].astype(dt_))
           + p["b_if"].astype(dt_)).astype(jnp.float32)[:, 0]
    i_raw, f_raw = jnp.split(if_, 2, -1)
    f = jax.nn.sigmoid(f_raw)
    i = jnp.exp(jnp.minimum(i_raw, I_GATE_CAP))
    v_aug = jnp.concatenate([v.astype(jnp.float32),
                             jnp.ones((B, H, 1), jnp.float32)], -1)
    new_state = (state * f[:, :, None, None]
                 + i[:, :, None, None] * jnp.einsum("bhn,bhp->bhnp",
                                                    k.astype(jnp.float32), v_aug))
    y_aug = jnp.einsum("bhn,bhnp->bhp", q.astype(jnp.float32), new_state)
    y, n = y_aug[..., :P], y_aug[..., P:]
    y = y / jnp.maximum(jnp.abs(n), 1.0)
    y = (y.reshape(B, d_inner).astype(dt_) * jax.nn.silu(z[:, 0]))[:, None]
    return jnp.einsum("ble,ed->bld", y, p["out_proj"].astype(dt_)), new_state


def init_mlstm_state(cfg: ModelConfig, batch: int):
    d_inner, H, P, N = _mdims(cfg)
    return jnp.zeros((batch, H, N, P + 1), jnp.float32)


# ------------------------------------------------------------------ sLSTM


def slstm_params(cfg: ModelConfig):
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    return {
        "w": ParamSpec((d, 4 * d), ("embed", "mlp")),
        "r": ParamSpec((H, hd, 4 * hd), ("heads", "head_dim", "mlp"),
                       "normal", scale=0.01),
        "b": ParamSpec((4 * d,), ("mlp",), "zeros"),
        "out_proj": ParamSpec((d, d), ("embed", "embed")),
    }


def apply_slstm(cfg: ModelConfig, p, x, state=None):
    """True recurrence: lax.scan over timesteps.  x: (B,L,d)."""
    B, L, d = x.shape
    H = cfg.n_heads
    hd = d // H
    dt_ = x.dtype
    wx = jnp.einsum("bld,de->ble", x, p["w"].astype(dt_)) + p["b"].astype(dt_)
    r = p["r"].astype(jnp.float32)

    if state is None:
        state = init_slstm_state(cfg, B)

    def step(carry, wx_t):
        h, c, n, m = carry                                   # each (B,d) f32
        hh = h.reshape(B, H, hd)
        rec = jnp.einsum("bhk,hke->bhe", hh, r).reshape(B, 4 * d)
        zi = wx_t.astype(jnp.float32) + rec
        z_, i_, f_, o_ = jnp.split(zi, 4, -1)
        # stabilized exponential gating
        m_new = jnp.maximum(f_ + m, i_)
        i_g = jnp.exp(i_ - m_new)
        f_g = jnp.exp(f_ + m - m_new)
        c_new = f_g * c + i_g * jnp.tanh(z_)
        n_new = f_g * n + i_g
        h_new = jax.nn.sigmoid(o_) * c_new / jnp.maximum(n_new, 1.0)
        return (h_new, c_new, n_new, m_new), h_new

    state, hs = jax.lax.scan(step, state, wx.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2).astype(dt_)
    return jnp.einsum("bld,de->ble", hs, p["out_proj"].astype(dt_)), state


def decode_slstm(cfg: ModelConfig, p, x, state):
    y, new_state = apply_slstm(cfg, p, x, state)
    return y, new_state


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, z, z - 1e9 * 0)  # h, c, n, m
