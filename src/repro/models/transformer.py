"""Model assembly: block definitions, scan-over-layers stacks, and the
three entry points every architecture exposes:

    forward(cfg, params, batch)            -> (logits, aux)      train/encode
    prefill(cfg, params, batch, window)    -> (logits, cache)    inference prefill
    decode_step(cfg, params, cache, tok, pos) -> (logits, cache) one-token decode

Homogeneous stacks (dense / moe / audio / vlm) are `lax.scan`ned over a
stacked-parameter pytree so the HLO stays O(1) in depth (essential for
the 94/96-layer archs).  Heterogeneous stacks (xlstm's sLSTM/mLSTM mix,
zamba2's mamba+shared-attention hybrid) use a python loop — they are
≤38 layers and the shared/irregular parameters don't fit a scan xs.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models import xlstm as XL
from repro.models.base import ParamSpec, init_params, is_spec

# ===================================================================== specs


def _stack(spec_tree, n: int):
    """Add a leading stacked-layers dim to every ParamSpec."""
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init,
                            s.scale, s.dtype),
        spec_tree, is_leaf=is_spec)


def dense_block_specs(cfg: ModelConfig):
    return {"ln1": L.norm_params(cfg), "attn": L.attn_params(cfg),
            "ln2": L.norm_params(cfg), "mlp": L.mlp_params(cfg)}


def moe_block_specs(cfg: ModelConfig):
    return {"ln1": L.norm_params(cfg), "attn": L.attn_params(cfg),
            "ln2": L.norm_params(cfg), "moe": MOE.moe_params(cfg)}


def mamba_block_specs(cfg: ModelConfig):
    return {"ln": L.norm_params(cfg), "ssm": SSM.ssm_params(cfg)}


def model_specs(cfg: ModelConfig):
    """Full parameter ParamSpec tree for an architecture."""
    at = cfg.arch_type
    specs: dict[str, Any] = {}
    if at == "audio":
        specs["frontend_proj"] = {
            "w": ParamSpec((cfg.frontend_dim, cfg.d_model), (None, "embed")),
            "b": ParamSpec((cfg.d_model,), ("embed",), "zeros")}
    else:
        specs["embed"] = L.embed_params(cfg)
    if at == "vlm":
        specs["img_proj"] = {
            "w": ParamSpec((cfg.frontend_dim, cfg.d_model), (None, "embed")),
            "b": ParamSpec((cfg.d_model,), ("embed",), "zeros")}

    if at in ("dense", "audio", "vlm"):
        specs["blocks"] = _stack(dense_block_specs(cfg), cfg.n_layers)
    elif at == "moe":
        specs["blocks"] = _stack(moe_block_specs(cfg), cfg.n_layers)
    elif at == "ssm":        # xlstm: per-layer list (mixed block kinds)
        specs["blocks"] = [
            {"ln": L.norm_params(cfg),
             **({"slstm": XL.slstm_params(cfg)} if i in cfg.slstm_at
                else {"mlstm": XL.mlstm_params(cfg)})}
            for i in range(cfg.n_layers)]
    elif at == "hybrid":     # zamba2: mamba stack + one shared attn block
        specs["blocks"] = [mamba_block_specs(cfg) for _ in range(cfg.n_layers)]
        specs["shared_attn"] = {"ln1": L.norm_params(cfg),
                                "attn": L.attn_params(cfg),
                                "ln2": L.norm_params(cfg),
                                "mlp": L.mlp_params(cfg)}
    else:
        raise ValueError(at)

    specs["final_norm"] = L.norm_params(cfg)
    specs["head"] = L.head_params(cfg)
    return specs


# ================================================================= embedding


def embed_inputs(cfg: ModelConfig, params, batch):
    """Produce the (B, S, d_model) input activations for any modality."""
    dt = cfg.compute_dtype
    at = cfg.arch_type
    if at == "audio":
        fp = params["frontend_proj"]
        return (jnp.einsum("bsf,fd->bsd", batch["frames"].astype(dt),
                           fp["w"].astype(dt)) + fp["b"].astype(dt))
    x = L.embed_tokens(params["embed"], batch["tokens"], dt)
    if at == "vlm" and "img_emb" in batch:
        ip = params["img_proj"]
        img = (jnp.einsum("bnf,fd->bnd", batch["img_emb"].astype(dt),
                          ip["w"].astype(dt)) + ip["b"].astype(dt))
        x = jnp.concatenate([img, x], axis=1)
    return x


# ============================================================= block forward


def _sp(cfg, x):
    """Sequence-parallel lever (§Perf): shard the residual stream's seq
    dim over `model` so remat-saved block inputs are 1/TP the bytes; XLA
    re-gathers at the qkv/mlp projections (RS+AG in place of the plain
    AR — same link bytes, TP× less live activation memory)."""
    if cfg.seq_parallel:
        from repro.models.base import maybe_constrain
        return maybe_constrain(x, "data", "model", None)
    return x


def _dense_block(cfg, p, x, pos_offset=0):
    x = _sp(cfg, x)
    x = x + L.full_attention(cfg, p["attn"], L.apply_norm(cfg, p["ln1"], x),
                             pos_offset=pos_offset)
    x = x + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], x))
    return x


def _moe_block(cfg, p, x, pos_offset=0):
    x = _sp(cfg, x)
    x = x + L.full_attention(cfg, p["attn"], L.apply_norm(cfg, p["ln1"], x),
                             pos_offset=pos_offset)
    y, aux = MOE.apply_moe(cfg, p["moe"], L.apply_norm(cfg, p["ln2"], x))
    return x + y, aux


# ================================================================== forward


def forward(cfg: ModelConfig, params, batch, *, return_hidden: bool = False):
    """Full-sequence forward (training / encoding).  Returns (logits, aux),
    or (hidden, aux) with ``return_hidden`` (the chunked-loss lever applies
    the LM head itself, bounding the fp32 logits buffer)."""
    x = embed_inputs(cfg, params, batch)
    at = cfg.arch_type
    aux = jnp.zeros((), jnp.float32)

    if at in ("dense", "audio", "vlm"):
        def body(h, bp):
            f = functools.partial(_dense_block, cfg)
            if cfg.remat:
                f = jax.checkpoint(f)
            return f(bp, h), None
        if cfg.scan_layers:
            x, _ = jax.lax.scan(lambda h, bp: body(h, bp), x, params["blocks"])
        else:
            for i in range(cfg.n_layers):
                bp = jax.tree.map(lambda a: a[i], params["blocks"])
                x, _ = body(x, bp)

    elif at == "moe":
        def mbody(carry, bp):
            h, a = carry
            f = functools.partial(_moe_block, cfg)
            if cfg.remat:
                f = jax.checkpoint(f)
            h, aux_l = f(bp, h)
            return (h, a + aux_l), None
        if cfg.scan_layers:
            (x, aux), _ = jax.lax.scan(mbody, (x, aux), params["blocks"])
        else:
            for i in range(cfg.n_layers):
                bp = jax.tree.map(lambda a: a[i], params["blocks"])
                (x, aux), _ = mbody((x, aux), bp)

    elif at == "ssm":
        for i, bp in enumerate(params["blocks"]):
            h = L.apply_norm(cfg, bp["ln"], x)
            if i in cfg.slstm_at:
                y, _ = XL.apply_slstm(cfg, bp["slstm"], h)
            else:
                y, _ = XL.apply_mlstm(cfg, bp["mlstm"], h)
            x = x + y

    elif at == "hybrid":
        sa = params["shared_attn"]
        for i, bp in enumerate(params["blocks"]):
            y, _ = SSM.apply_ssm(cfg, bp["ssm"], L.apply_norm(cfg, bp["ln"], x))
            x = x + y
            if cfg.attn_every and (i + 1) % cfg.attn_every == 0:
                x = _dense_block(cfg, sa, x)
    else:
        raise ValueError(at)

    x = L.apply_norm(cfg, params["final_norm"], x)
    if return_hidden:
        return x, aux
    return L.lm_logits(params["head"], x), aux


# ============================================================ prefill/decode


def _align_cache(t, S: int, window: int, seq_axis: int):
    """Place trailing-window keys into rolling-buffer slots (slot = pos % W).

    After a prefill of S tokens the buffer must satisfy the decode-side
    invariant ``cache[p % W] = key at absolute position p``.  For S < W the
    trailing keys already sit at slots 0..S-1 and we pad; for S ≥ W we roll
    the window by S mod W.
    """
    w = t.shape[seq_axis]
    if S < window:
        pad = [(0, 0)] * t.ndim
        pad[seq_axis] = (0, window - w)
        return jnp.pad(t, pad)
    return jnp.roll(t, S % window, axis=seq_axis)


def init_cache(cfg: ModelConfig, batch: int, window: int, dtype=None):
    """Abstract-friendly cache init (concrete zeros).

    ``cfg.cache_dtype`` (e.g. fp8) overrides the storage dtype — the
    §Perf lever that halves the decode memory term; reads upcast to the
    compute dtype inside decode_attention."""
    dt = dtype or cfg.cache_dtype or cfg.compute_dtype
    at = cfg.arch_type
    kv, hd = cfg.n_kv_heads, cfg.hd
    if at in ("dense", "vlm", "moe"):
        z = jnp.zeros((cfg.n_layers, batch, window, kv, hd), dt)
        cache = {"k": z, "v": z}
        if L.is_quantized_cache(cfg):
            s = jnp.zeros((cfg.n_layers, batch, window, kv, 1), jnp.float32)
            cache.update({"k_scale": s, "v_scale": s})
        return cache
    if at == "ssm":
        return [
            {"slstm": XL.init_slstm_state(cfg, batch)} if i in cfg.slstm_at
            else {"mlstm": XL.init_mlstm_state(cfg, batch)}
            for i in range(cfg.n_layers)]
    if at == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        return {
            "ssm": [SSM.init_ssm_cache(cfg, batch, dt)
                    for _ in range(cfg.n_layers)],
            "attn_k": jnp.zeros((n_attn, batch, window, kv, hd), dt),
            "attn_v": jnp.zeros((n_attn, batch, window, kv, hd), dt),
        }
    raise ValueError(f"no decode cache for arch_type={at}")


def prefill(cfg: ModelConfig, params, batch, window: int):
    """Encode a prompt, returning last-token logits + a decode cache."""
    x = embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    at = cfg.arch_type

    if at in ("dense", "vlm", "moe"):
        def body(h, bp):
            hn = L.apply_norm(cfg, bp["ln1"], h)
            a, ck, cv = L.prefill_cache(cfg, bp["attn"], hn, window=window)
            h = h + a
            hn2 = L.apply_norm(cfg, bp["ln2"], h)
            if at == "moe":
                y, _ = MOE.apply_moe(cfg, bp["moe"], hn2)
            else:
                y = L.apply_mlp(cfg, bp["mlp"], hn2)
            return h + y, (ck, cv)
        x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
        if L.is_quantized_cache(cfg):
            ks, ksc = L.quantize_kv(ks, cfg.cache_dtype)
            vs, vsc = L.quantize_kv(vs, cfg.cache_dtype)
            cache = {
                "k": _align_cache(ks, S, window, seq_axis=2),
                "v": _align_cache(vs, S, window, seq_axis=2),
                "k_scale": _align_cache(ksc, S, window, seq_axis=2),
                "v_scale": _align_cache(vsc, S, window, seq_axis=2)}
        else:
            cdt = cfg.cache_dtype or ks.dtype
            ks, vs = (_align_cache(t.astype(cdt), S, window, seq_axis=2)
                      for t in (ks, vs))
            cache = {"k": ks, "v": vs}

    elif at == "ssm":
        cache = []
        for i, bp in enumerate(params["blocks"]):
            h = L.apply_norm(cfg, bp["ln"], x)
            if i in cfg.slstm_at:
                y, st = XL.apply_slstm(cfg, bp["slstm"], h)
                cache.append({"slstm": st})
            else:
                y, st = XL.apply_mlstm(cfg, bp["mlstm"], h)
                cache.append({"mlstm": st})
            x = x + y

    elif at == "hybrid":
        sa = params["shared_attn"]
        ssm_cache, aks, avs = [], [], []
        for i, bp in enumerate(params["blocks"]):
            y, st = SSM.apply_ssm(cfg, bp["ssm"], L.apply_norm(cfg, bp["ln"], x))
            ssm_cache.append(st)
            x = x + y
            if cfg.attn_every and (i + 1) % cfg.attn_every == 0:
                hn = L.apply_norm(cfg, sa["ln1"], x)
                a, ck, cv = L.prefill_cache(cfg, sa["attn"], hn, window=window)
                x = x + a
                x = x + L.apply_mlp(cfg, sa["mlp"], L.apply_norm(cfg, sa["ln2"], x))
                cdt = cfg.cache_dtype or ck.dtype
                aks.append(_align_cache(ck.astype(cdt), S, window, seq_axis=1))
                avs.append(_align_cache(cv.astype(cdt), S, window, seq_axis=1))
        cache = {"ssm": ssm_cache,
                 "attn_k": jnp.stack(aks) if aks else jnp.zeros((0,)),
                 "attn_v": jnp.stack(avs) if avs else jnp.zeros((0,))}
    else:
        raise ValueError(f"prefill unsupported for {at}")

    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.lm_logits(params["head"], x[:, -1:])
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One-token decode.  tokens: (B,1) int32; pos: () int32 = context length."""
    dt = cfg.compute_dtype
    x = L.embed_tokens(params["embed"], tokens, dt)
    at = cfg.arch_type

    if at in ("dense", "vlm", "moe"):
        quant = L.is_quantized_cache(cfg)

        def body(h, xs):
            if quant:
                bp, ck, cv, ksc, vsc = xs
            else:
                bp, ck, cv = xs
                ksc = vsc = None
            hn = L.apply_norm(cfg, bp["ln1"], h)
            att = L.decode_attention(cfg, bp["attn"], hn, ck, cv, pos,
                                     k_scale=ksc, v_scale=vsc)
            a, new_c = att[0], att[1:]
            h = h + a
            hn2 = L.apply_norm(cfg, bp["ln2"], h)
            if at == "moe":
                y, _ = MOE.apply_moe(cfg, bp["moe"], hn2)
            else:
                y = L.apply_mlp(cfg, bp["mlp"], hn2)
            return h + y, new_c

        if quant:
            xs = (params["blocks"], cache["k"], cache["v"],
                  cache["k_scale"], cache["v_scale"])
            x, (ks, vs, ksc, vsc) = jax.lax.scan(body, x, xs)
            new_cache = {"k": ks, "v": vs, "k_scale": ksc, "v_scale": vsc}
        else:
            x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"],
                                                 cache["k"], cache["v"]))
            new_cache = {"k": ks, "v": vs}

    elif at == "ssm":
        new_cache = []
        for i, bp in enumerate(params["blocks"]):
            h = L.apply_norm(cfg, bp["ln"], x)
            if i in cfg.slstm_at:
                y, st = XL.decode_slstm(cfg, bp["slstm"], h, cache[i]["slstm"])
                new_cache.append({"slstm": st})
            else:
                y, st = XL.decode_mlstm(cfg, bp["mlstm"], h, cache[i]["mlstm"])
                new_cache.append({"mlstm": st})
            x = x + y

    elif at == "hybrid":
        sa = params["shared_attn"]
        new_ssm, n_attn = [], 0
        nk = cache["attn_k"]
        nv = cache["attn_v"]
        for i, bp in enumerate(params["blocks"]):
            c = cache["ssm"][i]
            y, st, buf = SSM.decode_ssm(cfg, bp["ssm"],
                                        L.apply_norm(cfg, bp["ln"], x),
                                        c["state"], c["conv"])
            new_ssm.append({"state": st, "conv": buf})
            x = x + y
            if cfg.attn_every and (i + 1) % cfg.attn_every == 0:
                hn = L.apply_norm(cfg, sa["ln1"], x)
                a, ck, cv = L.decode_attention(cfg, sa["attn"], hn,
                                               nk[n_attn], nv[n_attn], pos)
                nk = nk.at[n_attn].set(ck)
                nv = nv.at[n_attn].set(cv)
                x = x + a
                x = x + L.apply_mlp(cfg, sa["mlp"], L.apply_norm(cfg, sa["ln2"], x))
                n_attn += 1
        new_cache = {"ssm": new_ssm, "attn_k": nk, "attn_v": nv}
    else:
        raise ValueError(f"decode unsupported for {at}")

    x = L.apply_norm(cfg, params["final_norm"], x)
    return L.lm_logits(params["head"], x), new_cache


# =============================================================== convenience


def init_model(cfg: ModelConfig, key):
    return init_params(model_specs(cfg), key, cfg.param_dtype)
