"""Mamba2 (SSD) block — chunked state-space dual form.

TPU adaptation: the CUDA Mamba2 kernel's warp-level scan is replaced by
the chunked SSD algorithm — quadratic attention-like math *within* a
chunk (MXU einsums) and a `lax.scan` carry *between* chunks.  This keeps
peak memory at O(L·Q) instead of O(L²) and maps the sequential part onto
a length-L/Q scan, which is the TPU-idiomatic trade.

Decode is a single state update: S ← a·S + dt·x⊗B, y = C·S + D·x with a
(B, H, P, N) state carried in the serve cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import ParamSpec

CONV_W = 4


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def ssm_params(cfg: ModelConfig):
    d = cfg.d_model
    d_inner, H, P, N = _dims(cfg)
    return {
        "in_proj": ParamSpec((d, 2 * d_inner + 2 * N + H), ("embed", "mlp")),
        "conv": ParamSpec((CONV_W, d_inner + 2 * N), (None, "mlp"), "normal",
                          scale=0.1),
        "a_log": ParamSpec((H,), ("heads",), "zeros"),
        "d_skip": ParamSpec((H,), ("heads",), "ones"),
        "dt_bias": ParamSpec((H,), ("heads",), "zeros"),
        "out_proj": ParamSpec((d_inner, d), ("mlp", "embed")),
    }


def _split_proj(cfg, proj):
    d_inner, H, P, N = _dims(cfg)
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * N], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w):
    """Depthwise causal conv, width CONV_W. xbc: (B,L,C), w: (W,C)."""
    pad = jnp.pad(xbc, ((0, 0), (CONV_W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(CONV_W))
    return jax.nn.silu(out)


def _gates(p, dt_raw, a_log):
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,L,H)
    a = -jnp.exp(a_log.astype(jnp.float32))                          # (H,)
    log_decay = dt * a                                                # (B,L,H) ≤0
    return dt, log_decay


def apply_ssm(cfg: ModelConfig, p, x, state=None, pos=None):
    """Full-sequence forward.  x: (B, L, d).  Returns (y, final_state)."""
    B, L, d = x.shape
    d_inner, H, P, N = _dims(cfg)
    Q = min(cfg.ssm_chunk, L)
    dt_ = x.dtype

    proj = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(dt_))
    z, xbc_raw, dt_raw = _split_proj(cfg, proj)
    conv_tail = xbc_raw[:, -(CONV_W - 1):]          # decode re-entry buffer
    xbc = _causal_conv(xbc_raw, p["conv"].astype(dt_))
    xs, Bmat, Cmat = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    xs = xs.reshape(B, L, H, P)
    dt, log_decay = _gates(p, dt_raw, p["a_log"])

    # pad to a chunk multiple; padded steps get dt=0 (no input) and
    # log_decay=0 (decay 1) so they leave the carried state untouched.
    Lp = -(-L // Q) * Q
    if Lp != L:
        pad = Lp - L
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        log_decay = jnp.pad(log_decay, ((0, 0), (0, pad), (0, 0)))

    nc = Lp // Q
    xs_c = xs.reshape(B, nc, Q, H, P)
    B_c = Bmat.reshape(B, nc, Q, N).astype(jnp.float32)
    C_c = Cmat.reshape(B, nc, Q, N).astype(jnp.float32)
    dt_c = dt.reshape(B, nc, Q, H)
    ld_c = log_decay.reshape(B, nc, Q, H)
    cum = jnp.cumsum(ld_c, axis=2)                      # (B,nc,Q,H)

    # ---- intra-chunk (quadratic within chunk)
    # M[t,s] = exp(cum_t - cum_s) * (C_t·B_s) * dt_s, masked s<=t
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (B,nc,Q,Q,H)
    mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
    decay_m = jnp.where(mask[None, None, :, :, None], jnp.exp(rel), 0.0)
    cb = jnp.einsum("bntk,bnsk->bnts", C_c, B_c)                 # (B,nc,Q,Q)
    M = decay_m * cb[..., None] * dt_c[:, :, None, :, :]         # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bntsh,bnshp->bnthp", M,
                         xs_c.astype(jnp.float32))

    # ---- per-chunk summary state: S_n = Σ_s exp(cum_Q - cum_s) dt_s x_s B_s^T
    tail = jnp.exp(cum[:, :, -1:, :] - cum) * dt_c               # (B,nc,Q,H)
    chunk_state = jnp.einsum("bnsh,bnshp,bnsk->bnhpk",
                             tail, xs_c.astype(jnp.float32), B_c)

    # ---- inter-chunk recurrence
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # (B,nc,H)
    init = (jnp.zeros((B, H, P, N), jnp.float32) if state is None
            else state.astype(jnp.float32))

    def scan_fn(s, inp):
        cd, cs = inp
        s_new = s * cd[:, :, None, None] + cs
        return s_new, s                                          # emit state *entering* chunk

    final_state, entry_states = jax.lax.scan(
        scan_fn, init,
        (chunk_decay.transpose(1, 0, 2), chunk_state.transpose(1, 0, 2, 3, 4)))
    entry_states = entry_states.transpose(1, 0, 2, 3, 4)         # (B,nc,H,P,N)

    # ---- inter-chunk contribution: C_t · (exp(cum_t) * S_entry)
    y_inter = jnp.einsum("bntk,bnth,bnhpk->bnthp",
                         C_c, jnp.exp(cum), entry_states)

    y = (y_intra + y_inter).reshape(B, Lp, H, P)[:, :L]
    xs = xs[:, :L]
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = (y.reshape(B, L, d_inner) * jax.nn.silu(z.astype(jnp.float32)))
    out = jnp.einsum("ble,ed->bld", y.astype(dt_), p["out_proj"].astype(dt_))
    return out, {"state": final_state.astype(jnp.float32), "conv": conv_tail}


def decode_ssm(cfg: ModelConfig, p, x, state, conv_buf):
    """Single-token decode.  x: (B,1,d); state: (B,H,P,N);
    conv_buf: (B, CONV_W-1, d_inner+2N) rolling conv inputs."""
    B = x.shape[0]
    d_inner, H, P, N = _dims(cfg)
    dt_ = x.dtype
    proj = jnp.einsum("bld,de->ble", x, p["in_proj"].astype(dt_))
    z, xbc, dt_raw = _split_proj(cfg, proj)

    window = jnp.concatenate([conv_buf, xbc], axis=1)            # (B,W,C)
    new_buf = window[:, 1:]
    w = p["conv"].astype(dt_)
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w))[:, None]

    xs, Bmat, Cmat = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    xs = xs.reshape(B, 1, H, P).astype(jnp.float32)
    dt, log_decay = _gates(p, dt_raw, p["a_log"])                # (B,1,H)
    a = jnp.exp(log_decay)[:, 0]                                 # (B,H)
    upd = jnp.einsum("bh,bhp,bk->bhpk", dt[:, 0], xs[:, 0], Bmat[:, 0].astype(jnp.float32))
    new_state = state * a[:, :, None, None] + upd
    y = jnp.einsum("bk,bhpk->bhp", Cmat[:, 0].astype(jnp.float32), new_state)
    y = y + p["d_skip"][None, :, None] * xs[:, 0]
    y = y.reshape(B, 1, d_inner) * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum("ble,ed->bld", y.astype(dt_), p["out_proj"].astype(dt_))
    return out, new_state, new_buf


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_inner, H, P, N = _dims(cfg)
    return {
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, CONV_W - 1, d_inner + 2 * N), dtype),
    }
