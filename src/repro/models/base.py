"""Parameter/spec framework shared by every model in the zoo.

Models are pure functions over pytrees.  Each model declares its
parameters once as a nested dict of :class:`ParamSpec` (shape + logical
axis names + initializer); from that single declaration we derive
  * the initialized parameter pytree (``init_params``),
  * the PartitionSpec pytree for pjit (``partition_specs``), via the
    logical-axis rules in ``launch/sharding.py``,
  * byte/param accounting for the communication-cost model (eq. 9).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis vocabulary.  ``launch/sharding.py`` maps these to mesh axes.
#   layers   - stacked scan dimension (never sharded)
#   embed    - d_model
#   mlp      - feed-forward hidden
#   heads    - attention heads (q)
#   kv_heads - attention kv heads
#   head_dim - per-head dim
#   vocab    - vocabulary
#   expert   - MoE expert dimension
#   state    - SSM state dim
#   conv/spatial/channel - CNN dims (never sharded; FD-CNN is tiny)


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | embed
    scale: float | None = None    # stddev override; default fan-in
    dtype: Any = None             # override param dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaf_init(spec: ParamSpec, key, dtype):
    dt = spec.dtype or dtype
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "embed":
        std = spec.scale if spec.scale is not None else 0.02
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)
    # fan-in scaled normal (truncation unnecessary for our purposes)
    fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
    std = spec.scale if spec.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, key, dtype=jnp.float32):
    """Materialize a parameter pytree from a ParamSpec pytree."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = [_leaf_init(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(specs, dtype=jnp.float32):
    """ShapeDtypeStruct pytree (for dry-run lowering, no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype or dtype),
        specs, is_leaf=is_spec)


def logical_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def param_count(specs) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))


def param_bytes(specs, dtype_bytes: int = 4) -> int:
    return param_count(specs) * dtype_bytes


def tree_paths(tree, is_leaf=None):
    """List of '/'-joined key paths, flattened in tree order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)
    out = []
    for path, _leaf in flat:
        out.append("/".join(_path_str(p) for p in path))
    return out


def _path_str(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return str(p.idx)
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def maybe_constrain(x, *axes):
    """with_sharding_constraint iff the named mesh axes exist and divide
    the corresponding dim; no-op outside a mesh (CPU tests).  Used for
    intermediates whose sharding GSPMD can't infer (MoE dispatch buffers)
    or where we override its choice (sequence-parallel activations)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if mesh is None or mesh.empty:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax is not None and ax in sizes and dim % sizes[ax] == 0:
            spec.append(ax)
        else:
            spec.append(None)
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*spec))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)
