"""Top-k mixture-of-experts layer with sort-based token dispatch.

TPU-native design: instead of a (tokens × experts × capacity) one-hot
dispatch tensor (O(T·E·C) memory), token→expert assignments are sorted
by expert id and scattered into a dense (E, C, d) buffer, so the expert
computation is a pair of MXU-friendly batched einsums.  Tokens past an
expert's capacity are dropped (standard capacity-factor semantics); the
router aux loss keeps the load balanced so drops stay rare.

Experts are sharded over the ``expert`` logical axis (→ mesh ``model``),
which turns dispatch/return into all-to-alls under pjit — exactly the
collective pattern the roofline's collective term measures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import ParamSpec


def moe_params(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, e), ("embed", "expert")),
        "wi": ParamSpec((e, d, f), ("expert", "embed", "mlp")),
        "wg": ParamSpec((e, d, f), ("expert", "embed", "mlp")),
        "wo": ParamSpec((e, f, d), ("expert", "mlp", "embed")),
    }


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(n_tokens * cfg.experts_per_token * cfg.capacity_factor / cfg.n_experts)
    return max(128, -(-c // 128) * 128)   # 128-aligned (shardable over data)


# The (E, C, d) dispatch buffer is produced by a scatter whose sharding
# GSPMD cannot infer — without an explicit constraint it replicates the
# buffer on every device, which for granite (E=40, not divisible by the
# model axis) ballooned the train step to TBs of temp (EXPERIMENTS.md
# §Dry-run probe).  E → model when divisible, C → data.
from repro.models.base import maybe_constrain as _constrain


# Token-chunk size for the dispatch buffer: MoE over T tokens needs an
# (E, ~T·k·cf/E, d) buffer; chunking bounds it regardless of sequence length
# (prefill_32k is 1M tokens).  Chunks are independent → lax.scan.
MOE_TOKEN_CHUNK = 65_536


def apply_moe(cfg: ModelConfig, p, x):
    """x: (B, S, d) -> (y, aux_loss). Chunks tokens to bound dispatch memory."""
    B, S, d = x.shape
    T = B * S
    if T > MOE_TOKEN_CHUNK and T % MOE_TOKEN_CHUNK == 0:
        nc = T // MOE_TOKEN_CHUNK
        flat = x.reshape(nc, MOE_TOKEN_CHUNK, 1, d)

        def step(_, xc):
            y, aux = _moe_tokens(cfg, p, xc)
            return None, (y, aux)

        _, (ys, auxes) = jax.lax.scan(step, None, flat)
        return ys.reshape(B, S, d), jnp.mean(auxes)
    return _moe_tokens(cfg, p, x)


def _moe_tokens(cfg: ModelConfig, p, x):
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * S
    C = capacity(cfg, T)
    tok = x.reshape(T, d)
    dt = x.dtype

    logits = jnp.einsum("td,de->te", tok, p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, K)               # (T,K)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # ---- aux load-balance loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E), axis=0)
    router_mean = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_coef * E * jnp.sum(density * router_mean)

    # ---- sort-based dispatch
    flat_e = expert_idx.reshape(-1)                          # (T*K,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    src_tok = order // K                                     # token id per slot
    # position of each assignment within its expert's queue
    pos = jnp.arange(T * K) - jnp.searchsorted(sorted_e, sorted_e, side="left")
    keep = pos < C
    pos_c = jnp.where(keep, pos, 0)

    # dispatch precision (§Perf lever): the scatter→buf edge is the
    # token all-to-all when experts are model-sharded; storing the
    # buffer in fp8 halves those link bytes, compute stays in `dt`
    dd = cfg.moe_dispatch_dtype or dt
    buf = jnp.zeros((E, C, d), dd)
    # the (T·K, d) gather output feeding the scatter is also constrained —
    # GSPMD otherwise materializes it replicated (§Perf granite iter 7)
    expanded = _constrain(
        jnp.where(keep[:, None], tok[src_tok], 0).astype(dd), "data", None)
    buf = buf.at[sorted_e, pos_c].add(expanded)
    buf = _constrain(buf, "model", "data", None)

    # ---- expert computation (batched over E; sharded over `expert`)
    h = jnp.einsum("ecd,edf->ecf", buf.astype(dt), p["wi"].astype(dt))
    g = jnp.einsum("ecd,edf->ecf", buf.astype(dt), p["wg"].astype(dt))
    h = jax.nn.silu(h) * g
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt)).astype(dd)
    out_buf = _constrain(out_buf, "model", "data", None)

    # ---- return path: gather back, unsort, weight by gate
    gathered = out_buf[sorted_e, pos_c].astype(dt) * keep[:, None].astype(dt)
    unsorted = _constrain(jnp.zeros((T * K, d), dt).at[order].set(gathered),
                          "data", None)
    y = (unsorted.reshape(T, K, d)
         * gate[..., None].astype(dt)).sum(axis=1)
    return y.reshape(B, S, d), aux
