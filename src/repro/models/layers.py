"""Shared neural-net layers: norms, MLPs, embeddings, rotary GQA attention.

All functions are pure: ``f(cfg, params, x, ...) -> y``.  Attention
supports three execution modes used across the input-shape catalog:

  * full forward (train / prefill), causal or bidirectional, with an
    optional sliding-window band mask,
  * rolling-buffer KV-cache decode (one new token against a cache of
    ``W`` positions, where ``W = seq_len`` for full attention or the
    sliding window for the long-context variant).

The einsum path here is the reference implementation; the Pallas flash
kernel in ``repro.kernels.flash_attention`` is the TPU hot-path and is
validated against this math (see tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.base import ParamSpec

# ---------------------------------------------------------------- norms


def norm_params(cfg: ModelConfig, name: str = "norm"):
    d = cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": ParamSpec((d,), ("embed",), "ones"),
                "bias": ParamSpec((d,), ("embed",), "zeros")}
    return {"scale": ParamSpec((d,), ("embed",), "ones")}


def apply_norm(cfg: ModelConfig, p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        var = (xf ** 2).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------- MLP


def mlp_params(cfg: ModelConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_act == "silu_gated":
        return {"wi": ParamSpec((d, f), ("embed", "mlp")),
                "wg": ParamSpec((d, f), ("embed", "mlp")),
                "wo": ParamSpec((f, d), ("mlp", "embed"))}
    return {"wi": ParamSpec((d, f), ("embed", "mlp")),
            "wo": ParamSpec((f, d), ("mlp", "embed"))}


def apply_mlp(cfg: ModelConfig, p, x):
    h = jnp.einsum("...d,df->...f", x, p["wi"].astype(x.dtype))
    if cfg.mlp_act == "silu_gated":
        g = jnp.einsum("...d,df->...f", x, p["wg"].astype(x.dtype))
        h = jax.nn.silu(h) * g
    elif cfg.mlp_act == "relu_sq":        # nemotron-4 squared ReLU
        h = jnp.square(jax.nn.relu(h))
    elif cfg.mlp_act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------- embeddings


def embed_params(cfg: ModelConfig):
    return {"tok": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), "embed")}


def head_params(cfg: ModelConfig):
    return {"w": ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"))}


def embed_tokens(p, tokens, dtype):
    return jnp.take(p["tok"], tokens, axis=0).astype(dtype)


def lm_logits(p, x):
    return jnp.einsum("...d,dv->...v", x, p["w"].astype(x.dtype))


# ---------------------------------------------------------------- RoPE


def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs        # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                              # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention


def attn_params(cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    p = {"wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
         "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
         "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
         "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed"))}
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), "zeros")
        p["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), "zeros")
        p["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), "zeros")
    return p


def _qkv(cfg, p, x, positions):
    dt = x.dtype
    q = jnp.einsum("...d,dhk->...hk", x, p["wq"].astype(dt))
    k = jnp.einsum("...d,dhk->...hk", x, p["wk"].astype(dt))
    v = jnp.einsum("...d,dhk->...hk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B,S,H,hd) k: (B,T,KV,hd) -> (B,KV,G,S,T)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    return jnp.einsum("bskgh,btkh->bkgst", qg, k) / jnp.sqrt(hd).astype(q.dtype)


def _gqa_out(probs, v, p, dtype):
    B, KV, G, S, T = probs.shape
    o = jnp.einsum("bkgst,btkh->bskgh", probs.astype(dtype), v)
    o = o.reshape(B, S, KV * G, -1)
    return jnp.einsum("...hk,hkd->...d", o, p["wo"].astype(dtype))


NEG_INF = -1e30


def _band_mask(cfg: ModelConfig, qi, kj):
    """Boolean mask for query positions ``qi`` (Sq,1) vs key positions (1,Sk)."""
    mask = jnp.ones(jnp.broadcast_shapes(qi.shape, kj.shape), bool)
    if cfg.causal:
        mask &= kj <= qi
    if cfg.sliding_window is not None:
        mask &= (qi - kj) < cfg.sliding_window
        if not cfg.causal:
            mask &= (kj - qi) < cfg.sliding_window
    return mask


def _attend_chunked(cfg, q, k, v, p, dtype, q_chunk: int):
    """Scan over query chunks so peak score memory is Sq_chunk × Sk.

    The chunk body is checkpointed: a bare scan would SAVE each chunk's
    (Sq_chunk × Sk) probs for the backward pass, recreating the full S×S
    footprint it exists to avoid (§Perf granite iterations 2-4)."""
    B, S, H, hd = q.shape
    n = S // q_chunk
    qr = q.reshape(B, n, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def chunk_out(qc, i0):
        scores = _gqa_scores(qc, k).astype(jnp.float32)
        qi = (i0 + jnp.arange(q_chunk))[:, None]
        kj = jnp.arange(S)[None, :]
        scores = jnp.where(_band_mask(cfg, qi, kj), scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        return _gqa_out(probs, v, p, dtype)

    def step(_, qc_i):
        qc, i0 = qc_i
        return None, chunk_out(qc, i0)

    _, outs = jax.lax.scan(step, None, (qr, jnp.arange(n) * q_chunk))
    return outs.transpose(1, 0, 2, 3).reshape(B, S, -1)


# Above this sequence length the forward pass chunks queries (flash-style)
# instead of materializing the full S×S score matrix.
Q_CHUNK_THRESHOLD = 8_192
Q_CHUNK = 1_024


def _q_chunk_for(cfg: ModelConfig, S: int) -> int | None:
    if cfg.attn_q_chunk and S % cfg.attn_q_chunk == 0 and S > cfg.attn_q_chunk:
        return cfg.attn_q_chunk
    if S > Q_CHUNK_THRESHOLD and S % Q_CHUNK == 0:
        return Q_CHUNK
    return None


def full_attention(cfg: ModelConfig, p, x, *, pos_offset: int = 0):
    """Train / prefill attention over the whole sequence."""
    B, S, _ = x.shape
    positions = pos_offset + jnp.arange(S)[None, :]
    q, k, v = _qkv(cfg, p, x, positions)
    qc = _q_chunk_for(cfg, S)
    if qc is not None:
        return _attend_chunked(cfg, q, k, v, p, x.dtype, qc)
    scores = _gqa_scores(q, k).astype(jnp.float32)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(S)[None, :]
    scores = jnp.where(_band_mask(cfg, qi, kj), scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_out(probs, v, p, x.dtype)


def is_quantized_cache(cfg: ModelConfig) -> bool:
    return (cfg.cache_dtype is not None
            and jnp.dtype(cfg.cache_dtype).itemsize == 1)


def quantize_kv(t, qdtype):
    """Per-(batch, pos, kv-head) max-abs scaling into a 1-byte dtype.
    t: (B, S, KV, hd) -> (q: same shape in qdtype, scale: (B, S, KV, 1) f32)."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(scale, 1e-6)
    q = jnp.round(t.astype(jnp.float32) / scale * 127.0).astype(qdtype)
    return q, scale


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) / 127.0 * scale).astype(dtype)


def decode_attention(cfg: ModelConfig, p, x, cache_k, cache_v, pos,
                     k_scale=None, v_scale=None):
    """One-token decode against a rolling-buffer KV cache.

    x:        (B, 1, d_model)  — the new token's activations
    cache_k/v:(B, W, KV, hd)   — rolling buffer (W = window or full seq)
    pos:      ()  int32        — number of tokens already in context
    returns (out, new_cache_k, new_cache_v)
    """
    B, W = cache_k.shape[0], cache_k.shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _qkv(cfg, p, x, positions)

    slot = jnp.mod(pos, W)
    quant = k_scale is not None

    def upd(cache, t, axis=1):
        return jax.lax.dynamic_update_slice_in_dim(
            cache, t.astype(cache.dtype), slot, axis=axis)

    if quant:
        kq, ks = quantize_kv(k, cache_k.dtype)
        vq, vs = quantize_kv(v, cache_v.dtype)
        ck, cv = upd(cache_k, kq), upd(cache_v, vq)
        nks, nvs = upd(k_scale, ks), upd(v_scale, vs)
        k_full = dequantize_kv(ck, nks, q.dtype)
        v_full = dequantize_kv(cv, nvs, q.dtype)
    else:
        ck, cv = upd(cache_k, k), upd(cache_v, v)
        nks = nvs = None
        k_full, v_full = ck.astype(q.dtype), cv.astype(q.dtype)

    scores = _gqa_scores(q, k_full).astype(jnp.float32)  # (B,KV,G,1,W)
    idx = jnp.arange(W)
    valid = idx <= slot                       # written this far this wrap
    valid |= pos >= W                         # fully-wrapped buffer: all valid
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v_full, p, x.dtype)
    if quant:
        return out, ck, cv, nks, nvs
    return out, ck, cv


def prefill_cache(cfg: ModelConfig, p, x, *, window: int):
    """Run full attention AND return the trailing-``window`` KV cache."""
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(cfg, p, x, positions)
    qc = _q_chunk_for(cfg, S)
    if qc is not None:
        out = _attend_chunked(cfg, q, k, v, p, x.dtype, qc)
    else:
        scores = _gqa_scores(q, k).astype(jnp.float32)
        qi = jnp.arange(S)[:, None]
        kj = jnp.arange(S)[None, :]
        scores = jnp.where(_band_mask(cfg, qi, kj), scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(probs, v, p, x.dtype)
    w = min(window, S)
    return out, k[:, S - w:], v[:, S - w:]
