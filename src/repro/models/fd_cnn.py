"""FD-CNN — the fall-detection CNN used by the CEFL paper [He et al. 2019].

Input: (B, 20, 20, 3) RGB bitmap windows of 3-axial acceleration +
angular-velocity signals.  Architecture (paper §V-B): conv 5×5×3 →
maxpool 2×2 → conv 5×5×32 → maxpool 2×2 → fc 512 → fc 8 (softmax),
ReLU activations, Adam(1e-4), batch 32, cross-entropy.

The layer list order below *is* the CEFL layer order: the base/
personalized split (paper Step 4) selects a prefix of this list, and the
communication-cost model (eq. 9) sums per-layer byte sizes δ_l in this
order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.base import ParamSpec

IMG = 20
N_CLASSES = 8

FD_CNN_CONFIG = ModelConfig(
    name="fd_cnn", arch_type="cnn", n_layers=4, d_model=512, n_heads=1,
    n_kv_heads=1, d_ff=512, vocab=N_CLASSES, causal=False,
    learning_rate=1e-4, base_layers=2,
    citation="[He et al., IEEE Sensors J. 19(13), 2019; CEFL paper §V-B]")


def fd_cnn_specs(cfg: ModelConfig | None = None):
    # SAME padding: 20→20 →pool→ 10→10 →pool→ 5; flatten 5*5*32 = 800.
    return {
        "conv1": {"w": ParamSpec((5, 5, 3, 3), (None, None, None, None)),
                  "b": ParamSpec((3,), (None,), "zeros")},
        "conv2": {"w": ParamSpec((5, 5, 3, 32), (None, None, None, None)),
                  "b": ParamSpec((32,), (None,), "zeros")},
        "fc1": {"w": ParamSpec((5 * 5 * 32, 512), ("mlp", "embed")),
                "b": ParamSpec((512,), ("embed",), "zeros")},
        "fc2": {"w": ParamSpec((512, N_CLASSES), ("embed", "vocab")),
                "b": ParamSpec((N_CLASSES,), ("vocab",), "zeros")},
    }


# CEFL layer order (prefix-B base/personalized split, eq. 6-7, eq. 9)
FD_CNN_LAYER_ORDER = ("conv1", "conv2", "fc1", "fc2")


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return jax.nn.relu(y + b)


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def fd_cnn_forward(params, images):
    """images: (B, 20, 20, 3) -> logits (B, 8)."""
    x = images.astype(jnp.float32)
    x = _pool(_conv(x, params["conv1"]["w"], params["conv1"]["b"]))
    x = _pool(_conv(x, params["conv2"]["w"], params["conv2"]["b"]))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def fd_cnn_loss(params, batch):
    logits = fd_cnn_forward(params, batch["x"])
    labels = jax.nn.one_hot(batch["y"], N_CLASSES)
    loss = -jnp.mean(jnp.sum(labels * jax.nn.log_softmax(logits), axis=-1))
    return loss


def fd_cnn_accuracy(params, batch):
    logits = fd_cnn_forward(params, batch["x"])
    return jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))


def layer_sizes_bytes(dtype_bytes: int = 4) -> dict[str, int]:
    """δ_l of eq. 9: per-layer parameter bytes in CEFL layer order."""
    specs = fd_cnn_specs()
    out = {}
    for name in FD_CNN_LAYER_ORDER:
        n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
            specs[name], is_leaf=lambda t: isinstance(t, ParamSpec)))
        out[name] = n * dtype_bytes
    return out
