"""Optimizers (optax is not available offline; these are the standard
algorithms over pytrees, with the state layouts the sharding rules and
the ZeRO-style partitioner understand).

Adafactor keeps a *factored* second moment (row/col running averages)
for rank-≥2 parameters — the memory-policy lever that lets the 235B/340B
configs train on a 16 GB/chip v5e pod (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # first moment   (None for sgd/adafactor)
    nu: Any          # second moment  (factored {"row","col"} leaves for adafactor)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable
    update: Callable      # (grads, state, params, lr) -> (new_params, new_state)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def _is_factored(x) -> bool:
    return isinstance(x, dict) and set(x) == {"row", "col"}


def _zip_apply(fn, params, *trees):
    """Apply ``fn(p_leaf, *other_leaves)`` leafwise, where the other trees
    share params' structure but may hold dict-composites (factored nu) or
    be None.  Returns tuple-of-trees matching fn's tuple output."""
    p_leaves, treedef = jax.tree.flatten(params)
    others = []
    for t in trees:
        if t is None:
            others.append([None] * len(p_leaves))
        else:
            others.append(jax.tree.flatten(t, is_leaf=_is_factored)[0])
    outs = [fn(p, *o) for p, *o in zip(p_leaves, *others)]
    n_out = len(outs[0])
    return tuple(jax.tree.unflatten(treedef, [o[i] for o in outs])
                 for i in range(n_out))


# --------------------------------------------------------------------- adam


def adam(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0) -> Optimizer:
    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(f32, params), jax.tree.map(f32, params))

    def update(grads, state, params, lr):
        step = state.step + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            d = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                d = d + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype), m, v

        new_p, new_m, new_v = _zip_apply(upd, params, grads, state.mu, state.nu)
        return new_p, OptState(step, new_m, new_v)

    return Optimizer("adam", init, update)


def adamw(b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01) -> Optimizer:
    o = adam(b1, b2, eps, weight_decay)
    return Optimizer("adamw", o.init, o.update)


# ---------------------------------------------------------------- adafactor


def adafactor(decay=0.99, eps=1e-30, clip_threshold=1.0) -> Optimizer:
    """Factored second moment: for a rank-≥2 parameter keep row/col means
    over the last two dims — O(r+c) instead of O(r·c) state."""

    def init(params):
        def nu0(p):
            if p.ndim >= 2:
                return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return jnp.zeros(p.shape, jnp.float32)
        return OptState(jnp.zeros((), jnp.int32), None,
                        jax.tree.map(nu0, params))

    def update(grads, state, params, lr):
        step = state.step + 1

        def upd(p, g, nu):
            g32 = jnp.square(g.astype(jnp.float32)) + eps
            if p.ndim >= 2:
                row = decay * nu["row"] + (1 - decay) * g32.mean(-1)
                col = decay * nu["col"] + (1 - decay) * g32.mean(-2)
                r = row / (row.mean(-1, keepdims=True) + eps)
                vhat = r[..., None] * col[..., None, :]
                new_nu = {"row": row, "col": col}
            else:
                vhat = decay * nu + (1 - decay) * g32
                new_nu = vhat
            d = g.astype(jnp.float32) * jax.lax.rsqrt(vhat + eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(d)) + eps)
            d = d / jnp.maximum(1.0, rms / clip_threshold)
            return (p.astype(jnp.float32) - lr * d).astype(p.dtype), new_nu

        new_p, new_nu = _zip_apply(upd, params, grads, state.nu)
        return new_p, OptState(step, None, new_nu)

    return Optimizer("adafactor", init, update)


# ---------------------------------------------------------------------- sgd


def sgd(momentum=0.0) -> Optimizer:
    def init(params):
        mu = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
              if momentum else None)
        return OptState(jnp.zeros((), jnp.int32), mu, None)

    def update(grads, state, params, lr):
        step = state.step + 1
        if momentum:
            def upd(p, g, m):
                m = momentum * m + g.astype(jnp.float32)
                return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m
            new_p, new_mu = _zip_apply(upd, params, grads, state.mu)
            return new_p, OptState(step, new_mu, None)

        def upd1(p, g):
            return ((p.astype(jnp.float32)
                     - lr * g.astype(jnp.float32)).astype(p.dtype),)
        (new_p,) = _zip_apply(upd1, params, grads)
        return new_p, OptState(step, None, None)

    return Optimizer("sgd", init, update)


# ------------------------------------------------------------------ factory


def make_optimizer(name: str, **kw) -> Optimizer:
    return {"adam": adam, "adamw": adamw, "adafactor": adafactor,
            "sgd": sgd}[name](**kw)


def init_opt_state(opt: Optimizer, params):
    return opt.init(params)
