from repro.optim.optimizers import (OptState, adafactor, adam, adamw,
                                    global_norm, init_opt_state, make_optimizer,
                                    sgd)
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine
