"""Abstract (ShapeDtypeStruct) inputs + PartitionSpec trees for the
dry-run: every (architecture × input-shape × mesh) combination lowers
through these — no device allocation anywhere.

Shape semantics (assignment spec):
  train_4k    — train_step on (256, 4096) token batches (microbatched)
  prefill_32k — prefill of (32, 32768) prompts → last-token logits + cache
  decode_32k  — serve_step: ONE token, KV cache of 32768, batch 128
  long_500k   — serve_step: ONE token, 524288 context, batch 1;
                sub-quadratic archs keep O(1)/windowed state; the KV-cache
                sequence dim is sharded over the data axis(es)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, ModelConfig
from repro.configs.registry import decode_window, shape_config
from repro.launch.sharding import data_axes, param_pspecs, spec_for_leaf
from repro.models import transformer as T
from repro.models.base import is_spec
from repro.optim.optimizers import make_optimizer
from repro.train.steps import TrainState, init_train_state


def _dp(mesh):
    axes = data_axes(mesh)
    return axes if len(axes) > 1 else axes[0]


def _msize(mesh, name="model"):
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


# ------------------------------------------------------------ batch specs


def batch_struct(cfg: ModelConfig, shape_name: str,
                 micro: bool = True) -> dict[str, jax.ShapeDtypeStruct]:
    """Abstract input batch for train/prefill shapes."""
    shape = INPUT_SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    lead: tuple[int, ...]
    if shape.kind == "train" and micro and cfg.microbatch > 1:
        lead = (cfg.microbatch, B // cfg.microbatch)
    else:
        lead = (B,)

    def sds(*dims, dtype=jnp.int32):
        return jax.ShapeDtypeStruct(lead + dims, dtype)

    if cfg.arch_type == "audio":
        out = {"frames": sds(S, cfg.frontend_dim, dtype=f32)}
        if shape.kind == "train":
            out["labels"] = sds(S)
        return out
    if cfg.arch_type == "vlm":
        s_text = S - cfg.n_img_tokens
        out = {"tokens": sds(s_text),
               "img_emb": sds(cfg.n_img_tokens, cfg.frontend_dim, dtype=f32)}
        if shape.kind == "train":
            out["labels"] = sds(s_text)
        return out
    out = {"tokens": sds(S)}
    if shape.kind == "train":
        out["labels"] = sds(S)
    return out


def batch_pspecs(cfg: ModelConfig, shape_name: str, mesh,
                 micro: bool = True):
    shape = INPUT_SHAPES[shape_name]
    dp = _dp(mesh)
    lead = (None, dp) if (shape.kind == "train" and micro
                          and cfg.microbatch > 1) else (dp,)

    def ps(extra_dims: int):
        return P(*lead, *([None] * extra_dims))

    if cfg.arch_type == "audio":
        out = {"frames": ps(2)}
        if shape.kind == "train":
            out["labels"] = ps(1)
        return out
    if cfg.arch_type == "vlm":
        out = {"tokens": ps(1), "img_emb": ps(2)}
        if shape.kind == "train":
            out["labels"] = ps(1)
        return out
    out = {"tokens": ps(1)}
    if shape.kind == "train":
        out["labels"] = ps(1)
    return out


# ------------------------------------------------------------ state specs


def abstract_train_state(cfg: ModelConfig) -> TrainState:
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(lambda k: init_train_state(cfg, k), key)


def train_state_pspecs(cfg: ModelConfig, mesh, *, zero1: bool = False,
                       pod_stacked: bool = False):
    """PartitionSpecs for TrainState: params by logical-axis rules, the
    optimizer moments inherit the param spec (factored adafactor moments
    drop the corresponding trailing dim).  ``pod_stacked`` prepends the
    CEFL per-pod stack dim, sharded over the ``pod`` mesh axis."""
    specs = T.model_specs(cfg)
    pspecs = param_pspecs(specs, mesh)
    if zero1:
        from repro.launch.sharding import zero_extend
        axes = tuple(a for a in ("data", "pod")
                     if a in mesh.axis_names) if not pod_stacked else ("data",)
        pspecs = zero_extend(pspecs, specs, mesh, axes=axes)

    state_abs = abstract_train_state(cfg)
    p_leaves, _ = jax.tree.flatten(pspecs)

    def match_tree(moment_tree):
        """Map a moment pytree (params-structured, possibly with factored
        dict leaves) to pspecs derived from the param pspecs."""
        if moment_tree is None:
            return None
        fact = lambda x: isinstance(x, dict) and set(x) == {"row", "col"}
        m_leaves, m_def = jax.tree.flatten(moment_tree, is_leaf=fact)
        out = []
        for ps, m in zip(p_leaves, m_leaves):
            if fact(m):
                dims = list(ps) + [None] * (len(m["row"].shape) + 1 - len(ps))
                out.append({"row": P(*dims[:-1]),
                            "col": P(*(dims[:-2] + dims[-1:]))})
            else:
                dims = list(ps) + [None] * (len(m.shape) - len(ps))
                out.append(P(*dims[:len(m.shape)]))
        return jax.tree.unflatten(m_def, out)

    mu_ps = match_tree(state_abs.opt_state.mu)
    nu_ps = match_tree(state_abs.opt_state.nu)
    from repro.optim.optimizers import OptState
    st = TrainState(P(), pspecs, OptState(P(), mu_ps, nu_ps))
    if pod_stacked:
        def prepend(ps):
            if not isinstance(ps, P):
                return ps
            return P("pod", *ps)
        st = jax.tree.map(prepend, st,
                          is_leaf=lambda x: isinstance(x, P))
        # scalar step counters stay replicated but gain the stack dim
        st = TrainState(P("pod"), st.params,
                        OptState(P("pod"), st.opt_state.mu, st.opt_state.nu))
    return st


def serve_param_pspecs(cfg: ModelConfig, mesh):
    """Weight-stationary serving: params span the full mesh (model axis by
    logical rules + data/pod axes on the largest remaining dims).  Without
    this, a 340B/235B checkpoint is only model-axis sharded and exceeds
    per-chip HBM (probe_nem/probe_moe in EXPERIMENTS.md §Dry-run)."""
    from repro.launch.sharding import zero_extend
    specs = T.model_specs(cfg)
    pspecs = param_pspecs(specs, mesh)
    axes = tuple(a for a in ("data", "pod") if a in mesh.axis_names)
    return zero_extend(pspecs, specs, mesh, axes=axes)


# ------------------------------------------------------------ cache specs


def abstract_cache(cfg: ModelConfig, shape_name: str):
    shape = INPUT_SHAPES[shape_name]
    window = decode_window(cfg, shape_name)
    return jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, window))


def cache_pspecs(cfg: ModelConfig, shape_name: str, mesh):
    """Sharding for the serve cache.  batch → data axes (decode_32k);
    long_500k (batch 1) shards the cache sequence dim instead."""
    shape = INPUT_SHAPES[shape_name]
    dp = _dp(mesh)
    m = _msize(mesh)
    seq_sharded = shape.global_batch == 1
    cache_abs = abstract_cache(cfg, shape_name)

    def kv_like(leaf):       # (L, B, W, KV, hd)
        _, Bd, W, KV, hd = leaf.shape
        kv_ax = "model" if KV % m == 0 else None
        hd_ax = "model" if (kv_ax is None and hd % m == 0) else None
        if seq_sharded:
            return P(None, None, dp, kv_ax, hd_ax)
        return P(None, dp, None, kv_ax, hd_ax)

    def leaf_spec(leaf):
        shp = leaf.shape
        if len(shp) == 5:
            return kv_like(leaf)
        if len(shp) == 4:    # ssm state (B,H,P,N) or mlstm (B,H,N,P+1)
            h_ax = "model" if shp[1] % m == 0 else None
            return P(dp if not seq_sharded else None, h_ax, None, None)
        if len(shp) == 3:    # conv buffer (B, W-1, C)
            c_ax = "model" if shp[2] % m == 0 else None
            return P(dp if not seq_sharded else None, None, c_ax)
        if len(shp) == 2:    # slstm state (B, d)
            d_ax = "model" if shp[1] % m == 0 else None
            return P(dp if not seq_sharded else None, d_ax)
        return P(*([None] * len(shp)))

    return jax.tree.map(leaf_spec, cache_abs)


def decode_inputs(cfg: ModelConfig, shape_name: str):
    shape = INPUT_SHAPES[shape_name]
    toks = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return toks, pos


def decode_input_pspecs(cfg: ModelConfig, shape_name: str, mesh):
    shape = INPUT_SHAPES[shape_name]
    dp = _dp(mesh)
    tok_ps = P(dp, None) if shape.global_batch > 1 else P(None, None)
    return tok_ps, P()


def logits_pspec(cfg: ModelConfig, mesh, batch_sharded: bool = True):
    dp = _dp(mesh)
    v_ax = "model" if cfg.vocab % _msize(mesh) == 0 else None
    return P(dp if batch_sharded else None, None, v_ax)
