"""End-to-end training driver.

Two modes:
  * FL mode (the paper):  ``--mode fl``  runs CEFL / baselines on FD-CNN
    + synthetic MobiAct (core/fl.py) — the faithful reproduction path.
  * LM mode: ``--mode lm --arch <id>`` trains a reduced-config LM from
    the assigned-architecture zoo on the synthetic token stream (single
    host; the production mesh path is exercised by dryrun.py).

Examples:
  PYTHONPATH=src python -m repro.launch.train --mode fl --method cefl
  PYTHONPATH=src python -m repro.launch.train --mode lm --arch yi-6b \
      --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def run_fl(args):
    from repro.core.fl import (FLConfig, FLHarness, run_cefl, run_fedper,
                               run_individual, run_regular_fl)
    cfg = FLConfig(n_clients=args.clients, k_clusters=args.k,
                   t_rounds=args.rounds, local_episodes=args.episodes,
                   transfer_episodes=args.transfer_episodes,
                   data_scale=args.data_scale, seed=args.seed,
                   heterogeneity=args.heterogeneity)
    h = FLHarness(cfg)
    fn = {"cefl": run_cefl, "regular_fl": run_regular_fl,
          "fedper": run_fedper, "individual": run_individual}[args.method]
    t0 = time.time()
    r = fn(h)
    print(json.dumps({
        "method": r.name, "accuracy": r.accuracy,
        "comm_MB": r.comm_bytes / 1e6, "episodes": r.episodes,
        "history": r.history, "elapsed_s": time.time() - t0,
    }, indent=2))


def run_lm(args):
    from repro.configs.registry import get_config, smoke_config
    from repro.data.lm import synthetic_lm_stream
    from repro.train.steps import (init_train_state, make_train_step,
                                   split_microbatches)

    cfg = smoke_config(args.arch) if args.reduced else get_config(args.arch)
    cfg = cfg.with_(microbatch=args.microbatch)
    state = init_train_state(cfg, jax.random.PRNGKey(args.seed))
    step = jax.jit(make_train_step(cfg), donate_argnums=(0,))
    stream = synthetic_lm_stream(cfg, args.batch, args.seq, args.seed)
    t0 = time.time()
    for i in range(args.steps):
        batch = split_microbatches(cfg, jax.tree.map(jnp.asarray, next(stream)))
        state, m = step(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"{(time.time() - t0):.1f}s")
    print(f"final loss {float(m['loss']):.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="fl", choices=["fl", "lm"])
    # fl
    ap.add_argument("--method", default="cefl",
                    choices=["cefl", "regular_fl", "fedper", "individual"])
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--episodes", type=int, default=4)
    ap.add_argument("--transfer-episodes", type=int, default=40)
    ap.add_argument("--data-scale", type=float, default=0.5)
    ap.add_argument("--heterogeneity", type=float, default=0.5)
    # lm
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    (run_fl if args.mode == "fl" else run_lm)(args)


if __name__ == "__main__":
    main()
