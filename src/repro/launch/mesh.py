"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — jax locks the device count on
first backend init, and only ``dryrun.py`` sets the 512-placeholder-
device XLA flag.
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)                   # 256 v5e chips
MULTI_POD = (2, 16, 16)                 # 2 pods = 512 chips


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devices = jax.devices()[:need]      # dry-run host has 512 placeholders
    return jax.make_mesh(shape, axes, devices=devices,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(data: int = 2, model: int = 2, pods: int | None = None):
    """Small mesh for CPU tests (requires host-device-count flag set)."""
    if pods:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def required_devices(*, multi_pod: bool = False) -> int:
    shape = MULTI_POD if multi_pod else SINGLE_POD
    n = 1
    for s in shape:
        n *= s
    return n
