"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (deliverable g):

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = Σ per-op link-bytes / ICI_BW   (DCN-crossing ops split out)

``cost_analysis()`` provides per-device FLOPs / bytes for the SPMD
program.  Collective bytes are NOT in cost_analysis: we parse the
compiled HLO text, summing operand sizes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute, converted
to per-device link bytes with ring-algorithm factors:

    all-reduce      2·S·(g−1)/g        all-gather     S_out·(g−1)/g
    reduce-scatter  S_in·(g−1)/g       all-to-all     S·(g−1)/g
    collective-permute  S

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI; pod-crossing (DCN) bandwidth assumed 25 GB/s/chip
(recorded as an assumption — multi-pod numbers are qualitative).
"""
from __future__ import annotations

import dataclasses
import math
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
DCN_BW = 25e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes_total: int          # logical tensor bytes (result side)
    group_size: int
    link_bytes: float         # per-device bytes over links (ring model)
    crosses_pod: bool
    line: str


@dataclasses.dataclass
class Roofline:
    flops: float              # per device
    hbm_bytes: float          # per device
    ici_bytes: float          # per device over ICI links
    dcn_bytes: float          # per device over DCN
    collectives: list
    n_devices: int

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.ici_bytes / ICI_BW + self.dcn_bytes / DCN_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def summary(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "ici_bytes": self.ici_bytes, "dcn_bytes": self.dcn_bytes,
        }


def _result_bytes(line: str, op_match=None) -> int:
    """Sum the byte size of the op's *result* shape: the segment between
    the '=' and the op name, e.g. ``%ar = f32[4,8]{1,0} all-reduce(...)``
    (tuples for the -start halves of async pairs are summed)."""
    eq = line.find("=")
    end = op_match.start() if op_match is not None else len(line)
    lhs = line[eq + 1:end] if eq >= 0 else line[:end]
    sizes = []
    for m in _SHAPE_RE.finditer(lhs):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        sizes.append(n * _DTYPE_BYTES[dt])
    if not sizes:
        return 0
    # async -start ops carry (operand, result) tuples: take the largest
    # single buffer rather than double-counting both halves
    return max(sizes) if "-start" in line[:end] else sum(sizes)


def _group_info(line: str, pod_size: int | None):
    m = _GROUPS_RE.search(line)
    crosses = False
    if m:
        n, g = int(m.group(1)), int(m.group(2))
        gsize = g
        # iota groups [n,g]<=[dims]T(perm): materialize the device-id
        # grid exactly — a group crosses pods iff its members span
        # different id//pod_size blocks (a stride alone does NOT imply
        # pod crossing: within-pod data-axis groups are strided when the
        # pod axis is outermost).
        if pod_size:
            import numpy as np
            dims = [int(x) for x in m.group(3).split(",")]
            ids = np.arange(int(np.prod(dims))).reshape(dims)
            if m.group(4):
                perm = [int(x) for x in m.group(4).split(",")]
                ids = ids.transpose(perm)
            groups = ids.reshape(n, g)
            pods = groups // pod_size
            crosses = bool((pods != pods[:, :1]).any())
        return gsize, crosses
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        members = [int(x) for x in m.group(1).split(",") if x.strip()]
        gsize = max(len(members), 1)
        if pod_size:
            crosses = (max(members) // pod_size) != (min(members) // pod_size)
        return gsize, crosses
    return 1, False


def parse_collectives(hlo_text: str, n_devices: int,
                      pod_size: int | None = None) -> list[CollectiveOp]:
    ops = []
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _COLL_RE.search(line)
        if not m or line.startswith("ROOT %fusion"):
            continue
        # skip the -done halves of async pairs (size counted at -start)
        if re.search(r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)-done", line):
            continue
        kind = m.group(1)
        size = _result_bytes(line, m)
        if size == 0:
            continue
        g, crosses = _group_info(line, pod_size)
        if g <= 1:
            continue
        if kind == "all-reduce":
            link = 2 * size * (g - 1) / g
        elif kind == "reduce-scatter":
            link = size * (g - 1)          # result is 1/g of the input
        elif kind in ("all-gather", "all-to-all"):
            link = size * (g - 1) / g
        else:                      # collective-permute
            link = size
        ops.append(CollectiveOp(kind, size, g, link, crosses, line[:200]))
    return ops


def analyze(compiled, mesh, *, scan_overrides: dict | None = None) -> Roofline:
    """Build the roofline record from a compiled lowering."""
    n_dev = math.prod(mesh.devices.shape)
    pod_size = None
    if "pod" in mesh.axis_names:
        pod_size = n_dev // mesh.devices.shape[list(mesh.axis_names).index("pod")]
    ca = compiled.cost_analysis() or {}
    flops = float(ca.get("flops", 0.0))
    hbm = float(ca.get("bytes accessed", 0.0))
    txt = compiled.as_text()
    colls = parse_collectives(txt, n_dev, pod_size)
    # Collectives inside while/scan bodies execute once per iteration but
    # appear once in the HLO; callers may scale via scan_overrides
    # {substring: multiplier}.
    ici = dcn = 0.0
    for op in colls:
        mult = 1.0
        for key, m_ in (scan_overrides or {}).items():
            if key in op.line:
                mult = m_
        if op.crosses_pod:
            dcn += op.link_bytes * mult
        else:
            ici += op.link_bytes * mult
    return Roofline(flops, hbm, ici, dcn, colls, n_dev)


def model_flops(cfg, shape, n_active_params: int) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D prefill, 2·N·B decode."""
    if shape.kind == "train":
        return 6.0 * n_active_params * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active_params * shape.global_batch * shape.seq_len
    return 2.0 * n_active_params * shape.global_batch
