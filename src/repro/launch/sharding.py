"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Every parameter leaf carries logical axis names (models/base.py); this
module resolves them against a concrete mesh:

  * exactly one logical axis per leaf is mapped to the ``model`` mesh
    axis, chosen by priority (expert > vocab > mlp > heads > kv_heads >
    head_dim) among the axes whose size the mesh axis divides — this is
    what keeps granite's 24 heads or a 49155 vocab lowering instead of
    erroring (DESIGN.md §5);
  * ``data``/``pod`` never shard parameters in the baseline (pure DP —
    ZeRO-style param sharding is a §Perf hillclimb lever, see
    ``zero_extend``);
  * the leading client/pod stack dim (logical ``pods``) maps to the
    ``pod`` mesh axis for the CEFL pod-stacked state.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models.base import is_spec

# Note: head_dim is deliberately NOT in the priority list for parameters —
# when kv_heads doesn't divide the model axis (yi-6b kv=4, nemotron kv=8)
# the small KV projections are replicated rather than sharded on head_dim,
# which would put q (heads-sharded) and k (dim-sharded) in conflicting
# layouts and trigger SPMD full-rematerialization copies in every layer.
# Decode caches DO shard head_dim when kv doesn't divide (specs.cache_pspecs)
# because there the cache memory dominates and the 1-token q reshard is free.
MODEL_AXIS_PRIORITY = ("expert", "vocab", "mlp", "heads", "kv_heads")


def _mesh_size(mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def spec_for_leaf(axes: tuple, shape: tuple, mesh,
                  extra: dict | None = None) -> P:
    """Resolve one leaf's logical axes to a PartitionSpec."""
    msize = _mesh_size(mesh, "model")
    assign = [None] * len(shape)
    extra = extra or {}
    # explicit assignments first (e.g. {"pods": "pod"})
    for i, ax in enumerate(axes):
        if ax in extra and shape[i] % _mesh_size(mesh, extra[ax]) == 0:
            assign[i] = extra[ax]
    # one model-axis assignment by priority
    if "model" not in assign and msize > 1:
        order = {a: r for r, a in enumerate(MODEL_AXIS_PRIORITY)}
        cands = sorted(
            [(order[ax], i) for i, ax in enumerate(axes)
             if ax in order and assign[i] is None and shape[i] % msize == 0])
        if cands:
            assign[cands[0][1]] = "model"
    return P(*assign)


def param_pspecs(specs, mesh, *, pod_stacked: bool = False):
    """PartitionSpec pytree for a ParamSpec pytree."""
    extra = {"pods": "pod"} if pod_stacked else None

    def leaf(s):
        axes = (("pods",) + s.axes) if pod_stacked else s.axes
        shape = s.shape if not pod_stacked else ("POD",) + s.shape
        # shape for pod-stacked leaves is resolved by caller; here we only
        # need divisibility for real dims — treat the pod dim as divisible.
        if pod_stacked:
            msz = _mesh_size(mesh, "pod")
            shp = (msz,) + tuple(s.shape)
            return spec_for_leaf(axes, shp, mesh, extra)
        return spec_for_leaf(axes, s.shape, mesh, extra)

    return jax.tree.map(leaf, specs, is_leaf=is_spec)


def zero_extend(pspec_tree, specs, mesh, axes: tuple[str, ...] = ("data",)):
    """ZeRO/FSDP-style extension: additionally shard each leaf's largest
    still-unsharded divisible dim over each axis in ``axes``.

    Used (a) for big-arch training (params + optimizer state sharded over
    data; XLA inserts the fwd/bwd all-gathers — FSDP semantics) and (b)
    always for serving, where weights are stationary and should span the
    whole mesh.  The scan-stacked ``layers`` dim is never sharded (per-
    iteration dynamic-slices would cross devices every layer).
    """
    def leaf(ps, s):
        dims = list(ps)
        dims += [None] * (len(s.shape) - len(dims))
        for axis in axes:
            dsize = _mesh_size(mesh, axis)
            if dsize <= 1:
                continue
            best, best_i = 0, -1
            for i, d in enumerate(s.shape):
                if (dims[i] is None and s.axes[i] != "layers"
                        and d % dsize == 0 and d > best):
                    best, best_i = d, i
            if best_i >= 0:
                dims[best_i] = axis
        return P(*dims)

    return jax.tree.map(leaf, pspec_tree, specs, is_leaf=is_spec)


# ----------------------------------------------------- batch / cache specs


def batch_pspec(kind: str, mesh, *, seq_sharded: bool = False) -> P:
    """Leading-dims spec for input batches.

    train/prefill/decode: batch dim over (pod?, data).
    long-context decode (batch=1): the KV-cache *sequence* dim is
    sharded instead (``seq_sharded=True``).
    """
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    dp = tuple(axes) if len(axes) > 1 else axes[0]
    return dp


def data_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
