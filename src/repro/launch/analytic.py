"""Analytic roofline cost model, exact for this repo's architectures.

Why analytic: XLA's ``cost_analysis()`` counts a ``while``/scan body
ONCE (verified in tests/test_roofline.py), and every big config here
scans over layers and microbatches — so HLO-reported FLOPs understate
the true per-step cost by the scan trip counts.  We therefore compute
the three roofline terms from closed-form per-layer counts (we own the
model code; the formulas are exact for these einsums), and use the
compiled HLO for (a) ``memory_analysis`` (exact), (b) the collective
*schedule* (which ops, what shapes — with known trip-count multipliers),
(c) cross-validation on small unscanned variants where cost_analysis IS
exact (tests/test_roofline.py::test_analytic_matches_hlo).

All byte counts assume the config's compute dtype for activations and
param dtype for weights.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.configs.base import INPUT_SHAPES, ModelConfig
from repro.launch.roofline import DCN_BW, HBM_BW, ICI_BW, PEAK_FLOPS


@dataclasses.dataclass
class MeshShape:
    pod: int
    data: int
    model: int

    @property
    def n(self):
        return self.pod * self.data * self.model


def mesh_shape(mesh) -> MeshShape:
    d = dict(zip(mesh.axis_names, mesh.devices.shape))
    return MeshShape(d.get("pod", 1), d.get("data", 1), d.get("model", 1))


def _dtype_bytes(dt) -> int:
    return np.dtype(dt).itemsize


# ------------------------------------------------------------ param count


def param_counts(cfg: ModelConfig) -> dict:
    """total and active (per-token) parameter counts."""
    d, f, L, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    attn = d * hd * (H + 2 * KV) + H * hd * d
    if cfg.qkv_bias:
        attn += hd * (H + 2 * KV)
    gate_mult = 3 if cfg.mlp_act == "silu_gated" else 2
    if cfg.arch_type == "moe":
        expert = 3 * d * f                       # gated experts
        mlp_total = cfg.n_experts * expert + d * cfg.n_experts
        mlp_active = cfg.experts_per_token * expert + d * cfg.n_experts
        block_total = attn + mlp_total
        block_active = attn + mlp_active
        total = L * block_total + 2 * V * d
        active = L * block_active + 2 * V * d
    elif cfg.arch_type == "ssm":                 # xlstm
        d_in = 2 * d
        P = d_in // cfg.n_heads
        N = P // 2
        mlstm = d * 2 * d_in + d_in * cfg.n_heads * (2 * N + P) \
            + d_in * 2 * cfg.n_heads + d_in * d
        slstm = d * 4 * d + cfg.n_heads * (d // cfg.n_heads) * 4 * (d // cfg.n_heads) + d * d
        n_s = len(cfg.slstm_at)
        total = (L - n_s) * mlstm + n_s * slstm + 2 * V * d
        active = total
    elif cfg.arch_type == "hybrid":
        d_in = cfg.ssm_expand * d
        N = cfg.ssm_state
        Hs = d_in // cfg.ssm_head_dim
        mamba = d * (2 * d_in + 2 * N + Hs) + d_in * d + 3 * Hs
        shared = attn + gate_mult * d * f
        total = L * mamba + shared + 2 * V * d
        active = total
    else:
        mlp = gate_mult * d * f
        total = L * (attn + mlp) + 2 * V * d
        if cfg.arch_type == "audio":
            total += cfg.frontend_dim * d - V * d    # no input embed; proj
        if cfg.arch_type == "vlm":
            total += cfg.frontend_dim * d
        active = total
    return {"total": int(total), "active": int(active)}


# -------------------------------------------------------------- FLOPs


def step_flops(cfg: ModelConfig, shape_name: str) -> float:
    """Global FLOPs for one step of the shape's kind (all devices)."""
    shape = INPUT_SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    pc = param_counts(cfg)
    if shape.kind == "train":
        tokens = B * S
        base = 6.0 * pc["active"] * tokens
        attn_fl = _attn_flops(cfg, B, S) * 3.0        # fwd + 2x bwd
        if cfg.remat:
            base *= 4.0 / 3.0                          # one extra fwd
            attn_fl *= 4.0 / 3.0
        return base + attn_fl
    if shape.kind == "prefill":
        tokens = B * S
        return 2.0 * pc["active"] * tokens + _attn_flops(cfg, B, S)
    # decode: one token, attention reads the cache
    ctx = min(S, cfg.sliding_window or S)
    return 2.0 * pc["active"] * B + _decode_attn_flops(cfg, B, ctx)


def _attn_flops(cfg: ModelConfig, B: int, S: int) -> float:
    """Quadratic attention term (score + value contractions), forward."""
    if cfg.arch_type == "ssm":
        # chunked linear attention: per chunk Q² instead of S²
        Q = cfg.ssm_chunk or 256
        d_in = 2 * cfg.d_model
        return 2.0 * B * S * Q * (d_in // 2 + d_in) * cfg.n_layers
    if cfg.arch_type == "hybrid":
        Q = cfg.ssm_chunk
        d_in = cfg.ssm_expand * cfg.d_model
        ssm = 2.0 * B * S * Q * (cfg.ssm_state + d_in) * cfg.n_layers
        n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        win = min(S, cfg.sliding_window or S)
        attn = 4.0 * B * S * win * cfg.n_heads * cfg.hd * n_attn
        return ssm + attn
    win = min(S, cfg.sliding_window or S)
    return 4.0 * B * S * win * cfg.n_heads * cfg.hd * cfg.n_layers


def _decode_attn_flops(cfg: ModelConfig, B: int, ctx: int) -> float:
    if cfg.arch_type == "ssm":
        d_in = 2 * cfg.d_model
        return 2.0 * B * d_in * (d_in // 2) * cfg.n_layers
    if cfg.arch_type == "hybrid":
        d_in = cfg.ssm_expand * cfg.d_model
        ssm = 4.0 * B * d_in * cfg.ssm_state * cfg.n_layers
        n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        return ssm + 4.0 * B * ctx * cfg.n_heads * cfg.hd * n_attn
    return 4.0 * B * ctx * cfg.n_heads * cfg.hd * cfg.n_layers


# --------------------------------------------------------------- HBM bytes


def step_hbm_bytes(cfg: ModelConfig, shape_name: str) -> float:
    """Global HBM traffic for one step (all devices, both directions)."""
    shape = INPUT_SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    pc = param_counts(cfg)
    pbytes = pc["total"] * _dtype_bytes(cfg.param_dtype)
    abytes = _dtype_bytes(cfg.compute_dtype)
    d = cfg.d_model

    if shape.kind == "train":
        # params: read every microbatch (fwd+bwd) + optimizer read/write
        w_traffic = pbytes * (2 * cfg.microbatch + 3)
        act = B * S * d * abytes * cfg.n_layers * (2 if cfg.remat else 6)
        return w_traffic + act
    if shape.kind == "prefill":
        act = B * S * d * abytes * cfg.n_layers * 4
        return pbytes + act
    # decode: all params + the whole KV cache/state once
    return pbytes + cache_bytes(cfg, shape_name) + B * d * abytes * cfg.n_layers * 4


def cache_bytes(cfg: ModelConfig, shape_name: str) -> float:
    shape = INPUT_SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    abytes = _dtype_bytes(cfg.cache_dtype or cfg.compute_dtype)
    W = min(S, cfg.sliding_window or S)
    if cfg.arch_type == "ssm":
        d_in = 2 * cfg.d_model
        P = d_in // cfg.n_heads
        return cfg.n_layers * B * cfg.n_heads * (P // 2) * (P + 1) * 4.0
    if cfg.arch_type == "hybrid":
        d_in = cfg.ssm_expand * cfg.d_model
        Hs = d_in // cfg.ssm_head_dim
        ssm = cfg.n_layers * B * Hs * cfg.ssm_head_dim * cfg.ssm_state * 4.0
        n_attn = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        return ssm + n_attn * 2 * B * W * cfg.n_kv_heads * cfg.hd * abytes
    return cfg.n_layers * 2.0 * B * W * cfg.n_kv_heads * cfg.hd * abytes


# --------------------------------------------------------- collective bytes


def step_collective_bytes(cfg: ModelConfig, shape_name: str, ms: MeshShape,
                          *, mode: str = "ddp",
                          inner_steps: int = 1) -> dict:
    """Per-device link bytes per step, split ICI vs DCN (ring factors).

    mode: 'ddp'  — gradients all-reduced over data (and pod) every step;
          'cefl' — gradients all-reduced over data only; base-mask params
                   all-reduced over pod once per ``inner_steps`` steps
                   (the paper's partial aggregation, eq. 6-7).
    """
    shape = INPUT_SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    pc = param_counts(cfg)
    abytes = _dtype_bytes(cfg.compute_dtype)
    d = cfg.d_model
    tp = ms.model

    ici = 0.0
    dcn = 0.0

    def ring(sz, g):
        return 2.0 * sz * (g - 1) / g if g > 1 else 0.0

    # --- tensor-parallel activation all-reduces (per layer, fwd)
    if tp > 1:
        step_tokens = B * S if shape.kind != "decode" else B
        per_dev_tokens = step_tokens / max(ms.data * ms.pod, 1)
        act = per_dev_tokens * d * abytes
        n_ar = 2 * cfg.n_layers            # attn-out + mlp-out per layer
        if cfg.arch_type == "moe":
            # all-to-all dispatch+return when experts are sharded;
            # fp8 dispatch (§Perf lever) halves these bytes
            if cfg.n_experts % tp == 0:
                db = _dtype_bytes(cfg.moe_dispatch_dtype or cfg.compute_dtype)
                a2a = 2 * per_dev_tokens * cfg.experts_per_token * d * db
                a2a_mult = 3.0 if shape.kind == "train" else 1.0
                ici += a2a * (tp - 1) / tp * cfg.n_layers * a2a_mult
            n_ar = cfg.n_layers            # attn-out only
        mult = 3.0 if shape.kind == "train" else 1.0
        ici += ring(act, tp) / 2.0 * n_ar * mult   # one-shot AR ≈ S(g-1)/g

    # --- ZeRO/FSDP parameter all-gathers (fwd+bwd, once per microbatch)
    if cfg.zero1 and shape.kind == "train" and ms.data > 1:
        pbytes_shard = pc["total"] * _dtype_bytes(cfg.param_dtype) / tp
        ici += (2 * cfg.microbatch * pbytes_shard
                * (ms.data - 1) / ms.data)

    # --- data/pod-parallel gradient sync
    if shape.kind == "train":
        gbytes = pc["total"] * 4.0 / tp            # grads sharded over model
        if mode == "ddp":
            g_ici = ring(gbytes, ms.data)
            ici += g_ici
            if ms.pod > 1:
                dcn += ring(gbytes, ms.pod)
        else:  # cefl
            ici += ring(gbytes, ms.data)
            if ms.pod > 1:
                base_frac = _base_fraction(cfg)
                dcn += ring(gbytes * base_frac, ms.pod) / inner_steps

    # --- decode with sequence-sharded cache: softmax combine over data
    if shape.kind == "decode" and B == 1 and ms.data > 1:
        part = cfg.n_heads * cfg.hd * 4.0          # per-layer partial out
        ici += ring(part, ms.data) * cfg.n_layers

    # --- vocab-sharded logits all-gather (last token only for serve)
    if tp > 1 and cfg.vocab % tp == 0 and shape.kind != "train":
        ici += B * cfg.vocab * 4.0 * (tp - 1) / tp

    return {"ici": ici, "dcn": dcn}


def _base_fraction(cfg: ModelConfig) -> float:
    if cfg.base_predicate == "non_expert" and cfg.arch_type == "moe":
        pc = param_counts(cfg)
        expert_bytes = cfg.n_layers * 3 * cfg.d_model * cfg.d_ff * cfg.n_experts
        return max(0.0, 1.0 - expert_bytes / pc["total"])
    B = cfg.base_layers or cfg.n_layers // 2
    return B / cfg.n_layers


# ------------------------------------------------------------------ report


@dataclasses.dataclass
class AnalyticRoofline:
    flops_per_dev: float
    hbm_per_dev: float
    ici_per_dev: float
    dcn_per_dev: float
    model_flops: float
    hlo_useful_ratio: float | None = None

    @property
    def compute_s(self):
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def memory_s(self):
        return self.hbm_per_dev / HBM_BW

    @property
    def collective_s(self):
        return self.ici_per_dev / ICI_BW + self.dcn_per_dev / DCN_BW

    @property
    def dominant(self):
        t = {"compute": self.compute_s, "memory": self.memory_s,
             "collective": self.collective_s}
        return max(t, key=t.get)


def analytic_roofline(cfg: ModelConfig, shape_name: str, mesh,
                      *, mode: str = "ddp",
                      inner_steps: int = 1) -> AnalyticRoofline:
    ms = mesh_shape(mesh)
    fl = step_flops(cfg, shape_name) / ms.n
    hbm = step_hbm_bytes(cfg, shape_name) / ms.n
    coll = step_collective_bytes(cfg, shape_name, ms, mode=mode,
                                 inner_steps=inner_steps)
    pc = param_counts(cfg)
    from repro.launch.roofline import model_flops
    mf = model_flops(cfg, INPUT_SHAPES[shape_name], pc["active"])
    return AnalyticRoofline(fl, hbm, coll["ici"], coll["dcn"], mf)
