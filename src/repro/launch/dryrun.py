import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture × input shape × mesh) combination against the production
mesh built from 512 placeholder host devices — NO allocation; inputs are
ShapeDtypeStructs.  Proves the sharding config is coherent, prints
memory_analysis (fits/doesn't-fit evidence) and cost_analysis, and emits
the roofline record (HLO collective schedule + analytic terms) consumed
by EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--cefl]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
"""

import argparse
import json
import math
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import (ARCHS, applicable_shapes, decode_window,
                                    get_config, shape_config)
from repro.core.sharded import CEFLShardedConfig, make_fl_round
from repro.launch import analytic as A
from repro.launch import roofline as R
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.train.steps import make_decode_fn, make_prefill_fn, make_train_step


def _mem_dict(mem) -> dict:
    return {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "code_bytes": mem.generated_code_size_in_bytes,
    }


def _scan_trip_counts(cfg, shape_kind: str, mode: str) -> dict:
    """Known multipliers for collectives that live inside scan bodies
    (cost_analysis counts while bodies once; see launch/analytic.py)."""
    mult = {}
    if shape_kind == "train":
        layers = cfg.n_layers if cfg.scan_layers else 1
        mult["in_layer_scan"] = layers * max(cfg.microbatch, 1)
    return mult


_DTYPES = {"fp8": "float8_e4m3fn", "int8": "int8", "bf16": "bfloat16",
           "f32": "float32"}


def _parse_overrides(pairs):
    out = {}
    for kv in pairs or []:
        k, v = kv.split("=", 1)
        if v in _DTYPES:
            out[k] = getattr(jnp, _DTYPES[v])
        elif v in ("true", "false"):
            out[k] = v == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                out[k] = v
    return out


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               mode: str = "baseline", verbose: bool = True,
               overrides: dict | None = None) -> dict:
    """Lower + compile one combination.  mode: baseline | cefl | zero1.
    ``overrides`` applies ModelConfig fields (the §Perf lever knobs)."""
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = INPUT_SHAPES[shape_name]
    cfg = shape_config(get_config(arch), shape_name)
    if overrides:
        cfg = cfg.with_(**overrides)
    if shape.kind == "train":
        # per-microbatch batch must stay divisible by the data shards
        # (pod×data for the multi-pod DDP mesh; data within a pod for cefl)
        if mode == "cefl":          # per-pod batch, sharded over data=16
            eff_b, shards = shape.global_batch // 2, 16
        elif multi_pod:             # DDP over pod×data
            eff_b, shards = shape.global_batch, 2 * 16
        else:
            eff_b, shards = shape.global_batch, 16
        m = cfg.microbatch
        while m > 1 and (eff_b // m) % shards:
            m //= 2
        if m != cfg.microbatch:
            cfg = cfg.with_(microbatch=m)
            rec_note = f"microbatch clamped {m} for {shards} data shards"
        else:
            rec_note = None
    else:
        rec_note = None
    rec = {"arch": arch, "shape": shape_name, "mode": mode,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "multi_pod": multi_pod,
           "overrides": {k: str(v) for k, v in (overrides or {}).items()}}
    if rec_note:
        rec["note"] = rec_note

    with jax.set_mesh(mesh):
        if shape.kind == "train" and mode == "cefl":
            assert multi_pod, "CEFL pod protocol needs the pod axis"
            lowered = _lower_cefl_round(cfg, mesh, shape_name)
        elif shape.kind == "train":
            lowered = _lower_train(cfg, mesh, shape_name,
                                   zero1=(mode == "zero1" or cfg.zero1))
        elif shape.kind == "prefill":
            lowered = _lower_prefill(cfg, mesh, shape_name)
        else:
            lowered = _lower_decode(cfg, mesh, shape_name)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    rec["memory"] = _mem_dict(mem)
    ca = compiled.cost_analysis() or {}
    rec["hlo_flops_per_dev_body"] = float(ca.get("flops", 0.0))
    rec["hlo_bytes_per_dev_body"] = float(ca.get("bytes accessed", 0.0))

    hlo = R.analyze(compiled, mesh)
    rec["collective_schedule"] = _schedule_summary(hlo.collectives)
    rec["hlo_ici_bytes_once"] = hlo.ici_bytes
    rec["hlo_dcn_bytes_once"] = hlo.dcn_bytes

    ar = A.analytic_roofline(cfg, shape_name, mesh,
                             mode=("cefl" if mode == "cefl" else "ddp"),
                             inner_steps=8)
    rec["roofline"] = {
        "compute_s": ar.compute_s, "memory_s": ar.memory_s,
        "collective_s": ar.collective_s, "dominant": ar.dominant,
        "flops_per_dev": ar.flops_per_dev, "hbm_per_dev": ar.hbm_per_dev,
        "ici_per_dev": ar.ici_per_dev, "dcn_per_dev": ar.dcn_per_dev,
        "model_flops": ar.model_flops,
        "useful_ratio": (ar.model_flops
                         / (ar.flops_per_dev * math.prod(mesh.devices.shape))
                         if ar.flops_per_dev else None),
    }
    pc = A.param_counts(cfg)
    rec["params_total"] = pc["total"]
    rec["params_active"] = pc["active"]
    rec["elapsed_s"] = time.time() - t0
    if verbose:
        dom = rec["roofline"]["dominant"]
        print(f"[dryrun] {arch:24s} {shape_name:12s} mesh={rec['mesh']:9s} "
              f"mode={mode:8s} OK {rec['elapsed_s']:6.1f}s "
              f"dom={dom} mem(temp)={mem.temp_size_in_bytes/1e9:.2f}GB")
    return rec


def _schedule_summary(ops) -> list:
    agg: dict = {}
    for op in ops:
        key = (op.kind, op.group_size, op.crosses_pod)
        a = agg.setdefault(key, {"count": 0, "bytes": 0.0, "link_bytes": 0.0})
        a["count"] += 1
        a["bytes"] += op.bytes_total
        a["link_bytes"] += op.link_bytes
    return [{"kind": k[0], "group": k[1], "dcn": k[2], **v}
            for k, v in sorted(agg.items())]


# ------------------------------------------------------------- lowerings


def _lower_train(cfg, mesh, shape_name, *, zero1=False):
    step = make_train_step(cfg)
    state_abs = SP.abstract_train_state(cfg)
    state_ps = SP.train_state_pspecs(cfg, mesh, zero1=zero1)
    batch_abs = SP.batch_struct(cfg, shape_name)
    batch_ps = SP.batch_pspecs(cfg, shape_name, mesh)
    metrics_ps = {"loss": P(), "grad_norm": P(), "lr": P()}
    return jax.jit(step, in_shardings=(state_ps, batch_ps),
                   out_shardings=(state_ps, metrics_ps),
                   donate_argnums=(0,)).lower(state_abs, batch_abs)


def _lower_cefl_round(cfg, mesh, shape_name, n_pods: int = 2,
                      inner_steps: int = 2):
    """The paper's protocol at pod scale: ε local steps + base-only
    cross-pod partial aggregation (core/sharded.py)."""
    fl = CEFLShardedConfig(n_pods=n_pods, inner_steps=inner_steps,
                           mode="cefl")
    round_fn = make_fl_round(cfg, fl)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    from repro.core.sharded import init_pod_state
    state_abs = jax.eval_shape(
        lambda k: init_pod_state(cfg, k, n_pods), key)
    state_ps = SP.train_state_pspecs(cfg, mesh, pod_stacked=True)

    shape = INPUT_SHAPES[shape_name]
    per_pod = shape.global_batch // n_pods
    micro = cfg.microbatch
    one = SP.batch_struct(cfg, shape_name, micro=False)

    def expand(s):
        return jax.ShapeDtypeStruct(
            (inner_steps, n_pods, micro, per_pod // micro) + s.shape[1:],
            s.dtype)

    batch_abs = jax.tree.map(expand, one)
    bp = SP.batch_pspecs(cfg, shape_name, mesh, micro=False)

    def expand_ps(ps):
        return P(None, "pod", None, "data", *list(ps)[1:])

    batch_ps = jax.tree.map(expand_ps, bp,
                            is_leaf=lambda x: isinstance(x, P))
    metrics_ps = {"loss": P()}
    return jax.jit(round_fn, in_shardings=(state_ps, batch_ps),
                   out_shardings=(state_ps, metrics_ps),
                   donate_argnums=(0,)).lower(state_abs, batch_abs)


def _lower_prefill(cfg, mesh, shape_name):
    window = decode_window(cfg, shape_name) if cfg.arch_type != "audio" \
        else INPUT_SHAPES[shape_name].seq_len
    if cfg.arch_type == "audio":
        # encoder-only: "prefill" = full encode, no cache
        from repro.models import transformer as T

        def encode(params, batch):
            logits, _ = T.forward(cfg, params, batch)
            return logits

        params_abs = SP.abstract_train_state(cfg).params
        params_ps = SP.train_state_pspecs(cfg, mesh).params
        batch_abs = SP.batch_struct(cfg, shape_name)
        batch_ps = SP.batch_pspecs(cfg, shape_name, mesh)
        out_ps = SP.logits_pspec(cfg, mesh)
        return jax.jit(encode, in_shardings=(params_ps, batch_ps),
                       out_shardings=out_ps).lower(params_abs, batch_abs)

    fn = make_prefill_fn(cfg, window)
    params_abs = SP.abstract_train_state(cfg).params
    params_ps = SP.serve_param_pspecs(cfg, mesh)
    batch_abs = SP.batch_struct(cfg, shape_name)
    batch_ps = SP.batch_pspecs(cfg, shape_name, mesh)
    # cache layout must match decode-time expectations → same pspec fn,
    # but prefill caches are batch-sharded (the prompt batch is real)
    cache_ps = SP.cache_pspecs(cfg, shape_name, mesh)
    out_ps = (SP.logits_pspec(cfg, mesh), cache_ps)
    return jax.jit(fn, in_shardings=(params_ps, batch_ps),
                   out_shardings=out_ps).lower(params_abs, batch_abs)


def _lower_decode(cfg, mesh, shape_name):
    fn = make_decode_fn(cfg)
    params_abs = SP.abstract_train_state(cfg).params
    params_ps = SP.serve_param_pspecs(cfg, mesh)
    cache_abs = SP.abstract_cache(cfg, shape_name)
    cache_ps = SP.cache_pspecs(cfg, shape_name, mesh)
    toks, pos = SP.decode_inputs(cfg, shape_name)
    tok_ps, pos_ps = SP.decode_input_pspecs(cfg, shape_name, mesh)
    shape = INPUT_SHAPES[shape_name]
    batch_sharded = shape.global_batch > 1
    out_ps = (tok_ps, SP.logits_pspec(cfg, mesh, batch_sharded), cache_ps)
    return jax.jit(fn, in_shardings=(params_ps, cache_ps, tok_ps, pos_ps),
                   out_shardings=out_ps,
                   donate_argnums=(1,)).lower(params_abs, cache_abs,
                                              toks, pos)


# ------------------------------------------------------------------- main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--cefl", action="store_true",
                    help="lower the CEFL pod round for train shapes")
    ap.add_argument("--mode", default=None, help="baseline|cefl|zero1")
    ap.add_argument("--set", action="append", dest="overrides",
                    help="ModelConfig override key=value (perf levers), "
                         "e.g. --set attn_q_chunk=512 --set cache_dtype=fp8")
    ap.add_argument("--out", default=None, help="JSONL output path")
    args = ap.parse_args()
    overrides = _parse_overrides(args.overrides)

    combos = []
    archs = [a for a in ARCHS if a != "fd_cnn"] if (args.all or not args.arch) \
        else [args.arch.replace("_", "-")]
    for a in archs:
        cfg = get_config(a)
        shapes = applicable_shapes(cfg)
        if args.shape:
            shapes = [s for s in shapes if s == args.shape]
        for s in shapes:
            meshes = [args.multi_pod] if not args.both_meshes else [False, True]
            for mp in meshes:
                mode = args.mode or ("cefl" if (args.cefl and mp and
                                                INPUT_SHAPES[s].kind == "train")
                                     else "baseline")
                combos.append((a, s, mp, mode))

    results, failures = [], []
    for a, s, mp, mode in combos:
        try:
            rec = dryrun_one(a, s, multi_pod=mp, mode=mode,
                             overrides=overrides)
            results.append(rec)
        except Exception as e:  # noqa: BLE001 - record and continue
            failures.append((a, s, mp, mode, repr(e)))
            print(f"[dryrun] {a} {s} multi_pod={mp} mode={mode} FAILED: {e}")
            traceback.print_exc()
        if args.out:
            with open(args.out, "a") as f:
                for rec in results[len(results) - 1:]:
                    f.write(json.dumps(rec) + "\n")

    print(f"\n[dryrun] {len(results)} OK / {len(failures)} failed")
    for f_ in failures:
        print("  FAIL:", f_)
    return 0 if not failures else 1


if __name__ == "__main__":
    raise SystemExit(main())
