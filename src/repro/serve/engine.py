"""Batched serving engine: slot-based continuous batching over the
prefill/decode step functions (the same code paths the decode_32k /
long_500k dry-run shapes lower).

Design (vLLM-style, adapted to jit'd fixed shapes):
  * a fixed pool of ``batch_slots`` decode lanes, each owning one row of
    the batched rolling-buffer KV cache;
  * incoming requests are prefilled one-at-a-time (prompt length padded
    to ``prefill_pad`` buckets to bound recompiles) and their cache rows
    written into a free slot;
  * every engine tick runs ONE batched decode step for all active slots;
    finished requests (EOS or max_new_tokens) free their slot;
  * per-slot position counters let lanes be at different depths — the
    per-lane validity mask comes from each lane's own ``pos``.

The decode step here extends ``models.transformer.decode_step`` with a
per-lane ``pos`` vector (B,) instead of a scalar — a strictly more
general variant validated against the scalar path in tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    eos_id: int | None = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


def _decode_step_vector_pos(cfg: ModelConfig, params, cache, tokens, pos_vec):
    """decode_step with per-lane positions.  pos_vec: (B,) int32."""
    dt = cfg.compute_dtype
    x = L.embed_tokens(params["embed"], tokens, dt)
    assert cfg.arch_type in ("dense", "vlm", "moe"), cfg.arch_type

    def body(h, xs):
        bp, ck, cv = xs
        hn = L.apply_norm(cfg, bp["ln1"], h)
        a, ck, cv = _attend_vector_pos(cfg, bp["attn"], hn, ck, cv, pos_vec)
        h = h + a
        hn2 = L.apply_norm(cfg, bp["ln2"], h)
        if cfg.arch_type == "moe":
            from repro.models import moe as MOE
            y, _ = MOE.apply_moe(cfg, bp["moe"], hn2)
        else:
            y = L.apply_mlp(cfg, bp["mlp"], hn2)
        return h + y, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"],
                                         cache["k"], cache["v"]))
    x = L.apply_norm(cfg, params["final_norm"], x)
    return L.lm_logits(params["head"], x), {"k": ks, "v": vs}


def _attend_vector_pos(cfg, p, x, cache_k, cache_v, pos_vec):
    """Per-lane rolling-buffer attention (B lanes at different depths)."""
    B, W = cache_k.shape[0], cache_k.shape[1]
    positions = pos_vec[:, None]
    q, k, v = L._qkv(cfg, p, x, positions)
    slot = jnp.mod(pos_vec, W)                          # (B,)

    onehot = jax.nn.one_hot(slot, W, dtype=cache_k.dtype)  # (B, W)
    ck = cache_k * (1 - onehot[..., None, None]) + \
        onehot[..., None, None] * k.astype(cache_k.dtype)
    cv = cache_v * (1 - onehot[..., None, None]) + \
        onehot[..., None, None] * v.astype(cache_v.dtype)

    scores = L._gqa_scores(q, ck.astype(q.dtype)).astype(jnp.float32)
    idx = jnp.arange(W)[None, :]
    valid = (idx <= slot[:, None]) | (pos_vec[:, None] >= W)   # (B, W)
    scores = jnp.where(valid[:, None, None, None, :], scores, L.NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = L._gqa_out(probs, cv.astype(q.dtype), p, x.dtype)
    return out, ck, cv


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 window: int = 128, prefill_pad: int = 32):
        assert cfg.arch_type in ("dense", "vlm", "moe"), \
            "engine currently serves attention-cache archs"
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.window = window
        self.prefill_pad = prefill_pad

        self.cache = T.init_cache(cfg, batch_slots, window)
        self.pos = np.zeros(batch_slots, np.int32)       # context length
        self.budget = np.zeros(batch_slots, np.int32)    # tokens remaining
        self.owner: list[Request | None] = [None] * batch_slots
        self.next_tok = np.zeros((batch_slots, 1), np.int32)

        self._prefill = jax.jit(
            lambda p, b: T.prefill(cfg, p, b, window=window))
        self._decode = jax.jit(
            lambda p, c, t, pv: _decode_step_vector_pos(cfg, p, c, t, pv))

    # ------------------------------------------------------------- admit

    def _free_slot(self) -> int | None:
        for i, o in enumerate(self.owner):
            if o is None:
                return i
        return None

    def admit(self, req: Request) -> bool:
        """Prefill a request into a free slot.  False if engine full.

        The prompt is RIGHT-padded to a bucket (bounds recompiles); the
        lane then starts at pos = S−1 with the last prompt token queued,
        so the first tick re-decodes that position — an idempotent cache
        write — and emits the true first generated token.  Pad-position
        keys sit at slots ≥ S and are excluded by the validity mask.
        """
        i = self._free_slot()
        if i is None:
            return False
        S = len(req.prompt)
        pad = (-S) % self.prefill_pad
        toks = np.pad(req.prompt, (0, pad))[None, :]
        _, cache1 = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        for key in ("k", "v"):
            self.cache[key] = self.cache[key].at[:, i].set(cache1[key][:, 0])
        self.pos[i] = S - 1
        self.budget[i] = req.max_new_tokens
        self.owner[i] = req
        self.next_tok[i, 0] = int(req.prompt[-1])
        return True

    # -------------------------------------------------------------- tick

    @property
    def active(self) -> int:
        return sum(o is not None for o in self.owner)

    def tick(self):
        """One batched decode step for all lanes (idle lanes decode into
        their own slot harmlessly; their outputs are ignored)."""
        if self.active == 0:
            return
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.next_tok),
            jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for i, req in enumerate(self.owner):
            if req is None:
                continue
            self.pos[i] += 1
            tok = int(nxt[i])
            req.output.append(tok)
            self.budget[i] -= 1
            self.next_tok[i, 0] = tok
            if self.budget[i] <= 0 or (req.eos_id is not None
                                       and tok == req.eos_id):
                req.done = True
                self.owner[i] = None

    # --------------------------------------------------------------- run

    def run(self, requests: list[Request], max_ticks: int = 10_000):
        """Continuous batching: admit whenever a slot frees, tick until
        all requests complete."""
        queue = list(requests)
        ticks = 0
        while (queue or self.active) and ticks < max_ticks:
            while queue and self.admit(queue[0]):
                queue.pop(0)
            self.tick()
            ticks += 1
        return ticks
