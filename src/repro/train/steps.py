"""Training / serving step builders.

``make_train_step`` returns a pure ``(state, batch) -> (state, metrics)``
suitable for ``jax.jit`` under a mesh: the global batch is split into
``cfg.microbatch`` grad-accumulation microbatches processed by a
``lax.scan`` (bounding activation memory — mandatory for the 340B-class
configs), gradients are accumulated in fp32, clipped by global norm, and
fed to the configured optimizer.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.optim.optimizers import (Optimizer, clip_by_global_norm,
                                    make_optimizer)


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt_state: Any


def init_train_state(cfg: ModelConfig, key, opt: Optimizer | None = None):
    opt = opt or make_optimizer(cfg.optimizer)
    params = T.init_model(cfg, key)
    return TrainState(jnp.zeros((), jnp.int32), params, opt.init(params))


# ------------------------------------------------------------------- loss


def lm_loss(cfg: ModelConfig, params, batch):
    """Mean token cross-entropy (+ MoE aux).  Returns (loss, metrics).

    With ``cfg.loss_seq_chunk`` set, the LM head + softmax run per
    sequence chunk inside a scan (§Perf lever): the fp32
    (tokens × vocab) logits buffer — the single largest train-step
    temporary for big-vocab archs — is bounded by chunk × vocab.
    """
    if cfg.loss_seq_chunk:
        return _chunked_lm_loss(cfg, params, batch)
    logits, aux = T.forward(cfg, params, batch)
    labels = batch["labels"]
    if cfg.arch_type == "vlm":
        # image positions carry no labels; score text positions only
        logits = logits[:, cfg.n_img_tokens:]
    if cfg.arch_type != "audio" and cfg.causal:
        logits = logits[:, :-1]
        labels = labels[:, 1:]
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    xe = jnp.mean(lse - ll)
    return xe + aux, {"xe": xe, "aux": aux}


def _chunked_lm_loss(cfg: ModelConfig, params, batch):
    hidden, aux = T.forward(cfg, params, batch, return_hidden=True)
    labels = batch["labels"]
    if cfg.arch_type == "vlm":
        hidden = hidden[:, cfg.n_img_tokens:]
    if cfg.arch_type != "audio" and cfg.causal:
        hidden = hidden[:, :-1]
        labels = labels[:, 1:]
    B, S, d = hidden.shape
    C = cfg.loss_seq_chunk
    pad = (-S) % C
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    w = jnp.pad(jnp.ones((S,), jnp.float32), (0, pad))
    n = hidden.shape[1] // C
    hc = hidden.reshape(B, n, C, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, C).transpose(1, 0, 2)
    wc = w.reshape(n, C)
    head = params["head"]

    # checkpoint: without it the scan SAVES each chunk's (C × vocab) fp32
    # logits for the backward pass, defeating the chunking entirely
    # (§Perf granite iteration 3 — 40 GB/device of saved chunk logits)
    @jax.checkpoint
    def step_xe(h, lab, ww):
        logits = T.L.lm_logits(head, h).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - ll) * ww[None, :])

    def step(acc, xs):
        h, lab, ww = xs
        return acc + step_xe(h, lab, ww), None

    tot, _ = jax.lax.scan(step, jnp.zeros((), jnp.float32), (hc, lc, wc))
    xe = tot / (B * S)
    return xe + aux, {"xe": xe, "aux": aux}


# ------------------------------------------------------------- train step


def make_train_step(cfg: ModelConfig, opt: Optimizer | None = None,
                    lr_schedule: Callable | None = None,
                    loss_fn: Callable | None = None):
    opt = opt or make_optimizer(cfg.optimizer)
    lr_schedule = lr_schedule or (lambda s: jnp.asarray(cfg.learning_rate,
                                                        jnp.float32))
    loss_fn = loss_fn or lm_loss

    def micro_grads(params, micro):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, micro), has_aux=True)(params)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return grads, loss, metrics

    def train_step(state: TrainState, batch):
        """``batch`` leaves are (global_batch, ...) when cfg.microbatch == 1,
        else pre-split (microbatch, global_batch/microbatch, ...) — see
        ``split_microbatches``.  Pre-splitting happens host-side so the
        device layout never reshapes a data-sharded dim inside the jit."""
        n_micro = cfg.microbatch
        if n_micro > 1:
            split = batch
            lead = jax.tree.leaves(batch)[0].shape[0]
            if lead != n_micro:      # tolerate un-split input (tests)
                split = jax.tree.map(
                    lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                        + x.shape[1:]), batch)

            def acc_fn(acc, micro):
                g, loss, _ = micro_grads(state.params, micro)
                return jax.tree.map(jnp.add, acc,
                                    (g, {"loss": loss})), None

            zero = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params), {"loss": jnp.zeros((), jnp.float32)})
            (gsum, msum), _ = jax.lax.scan(acc_fn, zero, split)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = msum["loss"] / n_micro
        else:
            grads, loss, _ = micro_grads(state.params, batch)

        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = lr_schedule(state.step)
        new_params, new_opt = opt.update(grads, state.opt_state,
                                         state.params, lr)
        new_state = TrainState(state.step + 1, new_params, new_opt)
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return train_step


# ------------------------------------------------------------- serve steps


def split_microbatches(cfg: ModelConfig, batch):
    """(B, ...) -> (microbatch, B/microbatch, ...) host-side."""
    n = cfg.microbatch
    if n <= 1:
        return batch
    return jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_prefill_fn(cfg: ModelConfig, window: int):
    def prefill_fn(params, batch):
        return T.prefill(cfg, params, batch, window=window)
    return prefill_fn


def make_decode_fn(cfg: ModelConfig):
    def decode_fn(params, cache, tokens, pos):
        logits, cache = T.decode_step(cfg, params, cache, tokens, pos)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, cache
    return decode_fn
