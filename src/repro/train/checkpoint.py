"""Checkpointing: save/restore any pytree (TrainState, FL client stacks)
to a directory — .npz payload + JSON manifest (orbax is not available
offline; this is the same flatten-with-paths scheme, single-host).

Layout:  <dir>/<step>/manifest.json + arrays.npz
"""
from __future__ import annotations

import json
import os
import re

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths, leaves = [], []
    for path, leaf in flat:
        paths.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return paths, leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree) -> str:
    path = os.path.join(ckpt_dir, str(step))
    os.makedirs(path, exist_ok=True)
    paths, leaves, _ = _flatten(tree)
    arrays = {f"a{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(os.path.join(path, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "paths": paths,
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "shapes": [list(np.asarray(l).shape) for l in leaves],
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d) for d in os.listdir(ckpt_dir) if re.fullmatch(r"\d+", d)]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, template, step: int | None = None):
    """Restore into the structure of ``template`` (validates paths/shapes)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, str(step))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    t_paths, t_leaves, treedef = _flatten(template)
    if t_paths != manifest["paths"]:
        missing = set(manifest["paths"]) ^ set(t_paths)
        raise ValueError(f"checkpoint/template structure mismatch: {missing}")
    leaves = []
    for i, (tl, shp) in enumerate(zip(t_leaves, manifest["shapes"])):
        arr = data[f"a{i}"]
        # template leaves may be ShapeDtypeStructs (abstract) or arrays
        t_shape = tuple(tl.shape) if hasattr(tl, "shape") \
            else np.asarray(tl).shape
        if tuple(arr.shape) != t_shape:
            raise ValueError(
                f"shape mismatch at {t_paths[i]}: ckpt {arr.shape} vs "
                f"template {t_shape}")
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
