from repro.train.steps import (TrainState, init_train_state, lm_loss,
                               make_decode_fn, make_prefill_fn,
                               make_train_step)
