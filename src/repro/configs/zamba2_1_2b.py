"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attention block
[arXiv:2411.15242].

The single shared attention+MLP block (d_ff=8192) is applied every 6
Mamba2 layers with shared parameters, as in Zamba2.  Mamba2 state is
O(1) in sequence ⇒ long_500k runs natively (shared attention uses a
rolling window there).
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", arch_type="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    attn_every=6,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    optimizer="adamw", remat=True, microbatch=16,
    # §Perf levers: train_4k temp 79.0 -> 10.6 GB/dev
    loss_seq_chunk=1024,
    scan_layers=False,
    base_layers=19,
    citation="[arXiv:2411.15242]",
)
