"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B (family card)].
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", arch_type="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=27648, vocab=152064,
    qkv_bias=True, rope_theta=1e6,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    optimizer="adamw", remat=True, microbatch=8, zero1=True,
    # §Perf levers: train_4k temp 374.3 -> 8.7 GB/dev (fits v5e)
    seq_parallel=True, loss_seq_chunk=1024,
    base_layers=32,
    citation="[hf:Qwen/Qwen2.5-0.5B]",
)
