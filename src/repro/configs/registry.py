"""Architecture registry: ``--arch <id>`` resolution + smoke reduction."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.configs import (codeqwen15_7b, granite_moe_3b_a800m,
                           hubert_xlarge, nemotron_4_340b,
                           phi3_vision_4_2b, qwen25_32b,
                           qwen3_moe_235b_a22b, xlstm_350m, yi_6b,
                           zamba2_1_2b)
from repro.configs.base import INPUT_SHAPES, LONG_CONTEXT_WINDOW, ModelConfig
from repro.models.fd_cnn import FD_CNN_CONFIG

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in (
        hubert_xlarge.CONFIG,
        qwen3_moe_235b_a22b.CONFIG,
        yi_6b.CONFIG,
        granite_moe_3b_a800m.CONFIG,
        xlstm_350m.CONFIG,
        nemotron_4_340b.CONFIG,
        codeqwen15_7b.CONFIG,
        qwen25_32b.CONFIG,
        zamba2_1_2b.CONFIG,
        phi3_vision_4_2b.CONFIG,
        FD_CNN_CONFIG,
    )
}


def get_config(name: str) -> ModelConfig:
    for key in (name, name.replace("_", "-")):
        if key in ARCHS:
            return ARCHS[key]
    raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


# (arch, shape) applicability.  Skips are documented in DESIGN.md §4.
def applicable_shapes(cfg: ModelConfig) -> list[str]:
    if cfg.arch_type == "cnn":
        return []                       # FD-CNN runs the FL harness, not LM shapes
    if cfg.arch_type == "audio":        # encoder-only: no decode step
        return ["train_4k", "prefill_32k"]
    return list(INPUT_SHAPES)


def shape_config(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Per-shape config adjustments (sliding window for long-context dense)."""
    shape = INPUT_SHAPES[shape_name]
    if shape.name == "long_500k" and cfg.arch_type in ("dense", "vlm", "moe"):
        # full attention at 524k cache is infeasible → rolling-buffer window
        return cfg.with_(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg


def decode_window(cfg: ModelConfig, shape_name: str) -> int:
    """KV-cache buffer length for decode shapes."""
    shape = INPUT_SHAPES[shape_name]
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, shape.seq_len)
    return shape.seq_len


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model ≤ 512, ≤ 4 experts.

    Used by per-arch CPU smoke tests (one forward/train step, real
    allocation); the FULL configs are exercised only via the dry-run.
    """
    cfg = get_config(name)
    kw: dict = dict(
        n_layers=2, d_model=256, n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=64,
        d_ff=0 if cfg.d_ff == 0 else 512,
        vocab=512,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        remat=False, microbatch=1,
        base_layers=1,
        # reset perf levers: smoke tests exercise the plain paths (the
        # levers have their own dedicated equivalence tests)
        seq_parallel=False, loss_seq_chunk=0, attn_q_chunk=0,
        cache_dtype=None, moe_dispatch_dtype=None, zero1=False,
    )
    if cfg.n_experts:
        kw.update(n_experts=4, experts_per_token=2)
    if cfg.arch_type == "ssm":
        kw.update(slstm_at=(1,), ssm_chunk=8)
    if cfg.arch_type == "hybrid":
        kw.update(attn_every=1, ssm_state=16, ssm_head_dim=32, ssm_chunk=8)
    if cfg.arch_type == "audio":
        kw.update(frontend_dim=32)
    if cfg.arch_type == "vlm":
        kw.update(frontend_dim=32, n_img_tokens=4)
    return cfg.with_(**kw)


def smoke_shapes(cfg: ModelConfig) -> dict[str, tuple[int, int]]:
    """(batch, seq) per applicable shape-kind for smoke tests."""
    out = {"train": (2, 16), "prefill": (2, 16)}
    if cfg.arch_type != "audio":
        out["decode"] = (2, 16)
    return out
