"""Architecture configuration dataclass + input-shape catalog."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | audio | vlm | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention
    head_dim: int | None = None     # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True
    sliding_window: int | None = None   # rolling-buffer window (set per-shape)
    mlp_act: str = "silu_gated"     # silu_gated | gelu | relu_sq

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    moe_every: int = 1              # MoE layer frequency (1 = every layer)

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    attn_every: int = 0             # hybrid: shared attn block every k layers

    # xLSTM
    slstm_at: tuple[int, ...] = ()  # layer indices that are sLSTM blocks

    # modality frontend stub
    frontend_dim: int = 0           # hubert conv-feature dim / CLIP patch dim
    n_img_tokens: int = 0           # VLM image tokens prepended

    # numerics / memory policy
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    remat: bool = False
    scan_layers: bool = True

    # training
    optimizer: str = "adam"         # adam | adamw | adafactor | sgd
    learning_rate: float = 1e-4
    microbatch: int = 1             # grad-accumulation steps for train_4k
    zero1: bool = False             # ZeRO/FSDP: shard params+opt over data

    # CEFL partial-aggregation policy for this arch
    base_layers: int | None = None        # B: prefix length of base layers
    base_predicate: str = "prefix"        # prefix | non_expert

    norm: str = "rmsnorm"           # rmsnorm | layernorm
    tie_embeddings: bool = False

    # ---- §Perf hillclimb levers (beyond-paper; default off = baseline)
    seq_parallel: bool = False      # shard block-input activations' seq dim
                                    # over `model` (Korthikanti-style SP):
                                    # divides remat-saved bytes by TP size
    loss_seq_chunk: int = 0         # compute logits+xe in seq chunks of
                                    # this size (bounds the (tokens×vocab)
                                    # fp32 logits buffer); 0 = one shot
    cache_dtype: Any = None         # KV-cache storage dtype (e.g. fp8);
                                    # None = compute_dtype
    moe_dispatch_dtype: Any = None  # a2a dispatch/return precision for
                                    # expert buffers; None = compute_dtype
    attn_q_chunk: int = 0           # force flash-style q-chunked attention
                                    # at this chunk size even for short
                                    # sequences (bounds the S×S score
                                    # transient when heads can't shard);
                                    # 0 = auto (chunks only above 8k)

    citation: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# Sliding window used when a pure full-attention arch runs long_500k.
LONG_CONTEXT_WINDOW = 8_192
