"""yi-6b [dense]: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000
— llama-arch GQA [arXiv:2403.04652].
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", arch_type="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
    d_ff=11008, vocab=64000,
    rope_theta=5e6,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    optimizer="adamw", remat=True, microbatch=8, zero1=True,
    # §Perf levers (EXPERIMENTS.md): train_4k temp 23.0 -> 2.8 GB/dev
    seq_parallel=True, loss_seq_chunk=1024,
    base_layers=16,
    citation="[arXiv:2403.04652]",
)
