"""qwen3-moe-235b-a22b [moe]: 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

d_ff is the per-expert intermediate size.  head_dim=128 (64×128 > d_model,
as in Qwen3).  Trains with factored Adafactor second moment + bf16 params
so optimizer state fits v5e HBM (DESIGN.md §8).  CEFL partial aggregation
uses the ``non_expert`` base predicate: experts are the personalized
layers — the dominant byte volume stays out of the global sync.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", arch_type="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936,
    n_experts=128, experts_per_token=8,
    rope_theta=1e6,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    optimizer="adafactor", remat=True, microbatch=16, zero1=True,
    # §Perf: seq-parallel + chunked loss; fp8 a2a dispatch stays opt-in
    # (--set moe_dispatch_dtype=fp8: collective 68->45 s, temp -17 GB)
    seq_parallel=True, loss_seq_chunk=1024,
    base_predicate="non_expert", base_layers=47,
    citation="[hf:Qwen/Qwen3-30B-A3B]",
)
