"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base].

d_ff is the per-expert intermediate size.  24 heads do not divide the
16-way model mesh axis — the sharding rules fall back per DESIGN.md §5
(head axis replicated; mlp/expert axes sharded).
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", arch_type="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49155,
    n_experts=40, experts_per_token=8,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    optimizer="adamw", remat=True, microbatch=16, zero1=True,
    # §Perf hillclimb outcome (EXPERIMENTS.md): 24 heads can't shard over
    # the 16-way model axis → q-chunked attention bounds the replicated
    # S×S scores; chunked+checkpointed loss bounds the fp32 logits.
    # train_4k temp: 3214 GB/dev (naive) → 15.4 GB/dev (fits v5e).
    attn_q_chunk=512, loss_seq_chunk=1024,
    base_predicate="non_expert", base_layers=16,
    citation="[hf:ibm-granite/granite-3.0-1b-a400m-base]",
)
