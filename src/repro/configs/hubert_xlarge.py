"""hubert-xlarge [audio]: 48L d_model=1280 16H (GQA kv=16) d_ff=5120
vocab=504 — encoder-only, same arch as wav2vec2 [arXiv:2106.07447].

Backbone only: the conv waveform feature extractor is a stub; inputs are
precomputed 512-d frame embeddings (the conv encoder's output dim in the
HuBERT paper), projected to d_model.  Training objective is framewise
prediction over the 504 k-means cluster vocabulary (we predict all
frames; the paper masks — noted simplification).  Encoder-only ⇒ no
decode step: decode_32k / long_500k are skipped (DESIGN.md §4).
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", arch_type="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504,
    causal=False, norm="layernorm", mlp_act="gelu",
    frontend_dim=512,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    optimizer="adamw", remat=True, microbatch=8,
    base_layers=24,
    citation="[arXiv:2106.07447]",
)
