"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — GQA, squared-ReLU MLP [arXiv:2402.16819].

340B params: unfactored Adam state (≥12 B/param) exceeds a single v5e
pod's 4 TB HBM — config uses bf16 params + factored Adafactor second
moment and remat; the single-pod memory analysis in EXPERIMENTS.md
§Dry-run documents the margin.  head_dim = 18432/96 = 192.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", arch_type="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
    d_ff=73728, vocab=256000,
    mlp_act="relu_sq", norm="layernorm",
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    optimizer="adafactor", remat=True, microbatch=16, zero1=True,
    # §Perf levers: train_4k temp 71.1 -> 27.8 GB/dev (still >16 GB;
    # needs >=4 pods with pod-extended ZeRO - EXPERIMENTS.md pair C)
    seq_parallel=True, loss_seq_chunk=1024,
    base_layers=48,
    citation="[arXiv:2402.16819]",
)
