"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192
vocab=32064 — phi3-mini decoder + CLIP vision frontend
[hf:microsoft/Phi-3-vision-128k-instruct].

The CLIP ViT-L/14 image encoder is a stub per the assignment carve-out:
inputs carry 576 precomputed 1024-d patch embeddings which the trained
projector maps into the token stream ahead of the text tokens.  Total
sequence length (image + text tokens) equals the input-shape seq_len.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", arch_type="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064,
    frontend_dim=1024, n_img_tokens=576,
    rope_theta=10_000.0,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    optimizer="adamw", remat=True, microbatch=8,
    base_layers=16,
    citation="[hf:microsoft/Phi-3-vision-128k-instruct]",
)
