"""xlstm-350m [ssm]: 24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304
— sLSTM + mLSTM blocks [arXiv:2405.04517].

d_ff=0: xLSTM blocks carry their own up/down projections, no separate
FFN.  7:1 mLSTM:sLSTM ratio → sLSTM at layers (5, 13, 21).  Recurrent
state (not a KV cache) ⇒ long_500k runs natively.
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m", arch_type="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304,
    slstm_at=(5, 13, 21), ssm_chunk=256,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    optimizer="adamw", remat=True, microbatch=16,
    # §Perf levers: train_4k temp 19.1 -> 2.6 GB/dev
    loss_seq_chunk=1024,
    scan_layers=False,
    base_layers=12,
    citation="[arXiv:2405.04517]",
)
