"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (GQA kv=32 = MHA)
d_ff=13440 vocab=92416 — qwen1.5 arch, QKV bias [hf:Qwen/CodeQwen1.5-7B].
"""
import jax.numpy as jnp

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", arch_type="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab=92416,
    qkv_bias=True, rope_theta=1e6,
    param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
    optimizer="adamw", remat=True, microbatch=8, zero1=True,
    # §Perf levers: train_4k temp 23.0 -> 3.6 GB/dev
    seq_parallel=True, loss_seq_chunk=1024,
    base_layers=16,
    citation="[hf:Qwen/CodeQwen1.5-7B]",
)
