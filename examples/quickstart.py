"""Quickstart: the CEFL pipeline end-to-end in ~a minute on CPU.

Builds a small federated MobiAct-like corpus, runs the paper's four
steps (similarity graph → Louvain clustering → leader FL with partial
aggregation → transfer learning) and prints the accuracy/communication
trade against Regular FL.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core.fl import FLConfig, FLHarness, run_cefl, run_regular_fl

cfg = FLConfig(
    n_clients=12,          # paper uses 67 (MobiAct subjects)
    k_clusters=2,          # paper's optimal K (Fig. 3)
    t_rounds=8,            # paper uses T=100
    local_episodes=2,      # paper's ε=8
    transfer_episodes=12,  # paper's η=350
    warmup_episodes=1,
    data_scale=0.4,
    seed=0,
)

t0 = time.time()
h = FLHarness(cfg)
print(f"built {h.n} clients "
      f"({[len(c) for c in h.data.clients]} samples each)")

cefl = run_cefl(h)
reg = run_regular_fl(h)

led = cefl.extras["ledger"]
print(f"\nclusters: {cefl.extras['labels'].tolist()}")
print(f"leaders:  {cefl.extras['leaders']}")
print(f"\n{'':16s}{'accuracy':>10s}{'comm (MB)':>12s}")
print(f"{'Regular FL':16s}{reg.accuracy:10.3f}{reg.comm_bytes/1e6:12.2f}")
print(f"{'CEFL':16s}{cefl.accuracy:10.3f}{cefl.comm_bytes/1e6:12.2f}")
print(f"\nCEFL ledger: clustering={led.clustering_upload/1e6:.2f}MB "
      f"fl_up={led.fl_upload/1e6:.2f}MB fl_bcast={led.fl_broadcast/1e6:.2f}MB "
      f"transfer={led.transfer/1e6:.2f}MB")
print(f"savings: {100*(1 - cefl.comm_bytes/reg.comm_bytes):.2f}% "
      f"(paper: 98.45%)  [{time.time()-t0:.0f}s]")
