"""End-to-end LM training driver (deliverable b): train a member of the
yi/llama family on the synthetic Markov token stream and watch held-out
loss fall.

Defaults are CPU-container-sized (~8M params, ~2 minutes); pass
``--d-model 768 --layers 12 --vocab 16384`` for the ~100M-param variant
on real hardware (same code path; the 6B-and-up members of this family
are exercised via the production dry-run in repro.launch.dryrun).

    PYTHONPATH=src python examples/train_lm_e2e.py [--steps 150]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.lm import synthetic_lm_batch, synthetic_lm_stream
from repro.launch.analytic import param_counts
from repro.train.steps import (init_train_state, lm_loss, make_train_step,
                               split_microbatches)

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=150)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--d-model", type=int, default=256)
ap.add_argument("--layers", type=int, default=4)
ap.add_argument("--vocab", type=int, default=2048)
args = ap.parse_args()

cfg = get_config("yi-6b").with_(
    n_layers=args.layers, d_model=args.d_model, n_heads=4, n_kv_heads=4,
    head_dim=64, d_ff=3 * args.d_model, vocab=args.vocab,
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
    remat=False, microbatch=2, learning_rate=1e-3, zero1=False)
print(f"model: {param_counts(cfg)['total']/1e6:.1f}M params "
      f"({cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab})")

state = init_train_state(cfg, jax.random.PRNGKey(0))
step = jax.jit(make_train_step(cfg), donate_argnums=(0,))
stream = synthetic_lm_stream(cfg, args.batch, args.seq)
eval_batch = jax.tree.map(jnp.asarray,
                          synthetic_lm_batch(cfg, 8, args.seq, seed=9999))
eval_loss = jax.jit(lambda p: lm_loss(cfg, p, eval_batch)[0])

ev0 = float(eval_loss(state.params))
print(f"held-out loss before training: {ev0:.4f}")
t0 = time.time()
for i in range(args.steps):
    batch = split_microbatches(cfg, jax.tree.map(jnp.asarray, next(stream)))
    state, m = step(state, batch)
    if (i + 1) % 30 == 0 or i == args.steps - 1:
        ev = float(eval_loss(state.params))
        tok_s = args.batch * args.seq * (i + 1) / (time.time() - t0)
        print(f"step {i+1:4d}  train {float(m['loss']):7.4f}  "
              f"eval {ev:7.4f}  {tok_s:8.0f} tok/s")

ev1 = float(eval_loss(state.params))
print(f"\nheld-out loss {ev0:.3f} -> {ev1:.3f} over {args.steps} steps "
      f"({time.time()-t0:.0f}s)")
assert ev1 < ev0, "training must reduce held-out loss"
print("OK")
