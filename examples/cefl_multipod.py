"""CEFL as a datacenter protocol (DESIGN.md §3): two "pods" (client
replica groups) train locally and exchange only base-layer weights once
per round; a final transfer collective ships the leader's model to the
member pod.  Runs unsharded on CPU; the identical functions lower onto
the 2×16×16 production mesh in launch/dryrun.py --cefl.

    PYTHONPATH=src python examples/cefl_multipod.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import smoke_config
from repro.core.sharded import (CEFLShardedConfig, init_pod_state,
                                make_fl_round, make_transfer,
                                sync_bytes_per_round)
from repro.data.lm import synthetic_lm_batch

cfg = smoke_config("yi-6b").with_(learning_rate=1e-3)
fl = CEFLShardedConfig(n_pods=2, inner_steps=4, mode="cefl")
round_fn = jax.jit(make_fl_round(cfg, fl))
state = init_pod_state(cfg, jax.random.PRNGKey(0), fl.n_pods)


def make_batches(seed):
    """(inner_steps, n_pods, B, S) — each pod sees its own data stream."""
    rows = []
    for s in range(fl.inner_steps):
        pods = [synthetic_lm_batch(cfg, 4, 32, seed=seed + 100 * s + p)
                for p in range(fl.n_pods)]
        rows.append(jax.tree.map(lambda *y: jnp.stack(y), *pods))
    return jax.tree.map(lambda *x: jnp.stack(list(map(jnp.asarray, x))), *rows)


for r in range(3):
    state, m = round_fn(state, make_batches(r * 1000))
    head = np.asarray(state.params["head"]["w"], np.float32)
    emb = np.asarray(state.params["embed"]["tok"], np.float32)
    print(f"round {r}: loss {float(m['loss']):.4f}  "
          f"base(embed) pods equal: {np.allclose(emb[0], emb[1])}  "
          f"personalized(head) diverged: {not np.allclose(head[0], head[1])}")

p_one = jax.tree.map(lambda x: x[0], state.params)
print(f"\ncross-pod bytes/round: CEFL "
      f"{sync_bytes_per_round(cfg, p_one, 'cefl')/1e6:.2f}MB vs DDP "
      f"{sync_bytes_per_round(cfg, p_one, 'regular')/1e6:.2f}MB "
      f"x {fl.inner_steps} steps")

transfer = make_transfer(cfg, fl, leader_of=(0, 0))   # pod 0 leads
state = transfer(state)
head = np.asarray(state.params["head"]["w"], np.float32)
print(f"after transfer (eq. 8): pods identical: "
      f"{np.allclose(head[0], head[1])}")
