"""Batched serving example (deliverable b): prefill a prompt batch, then
greedy-decode continuations with the rolling-buffer KV cache — the same
prefill/decode_step code path the decode_32k / long_500k dry-run shapes
lower, including a sliding-window variant and an SSM (state-carrying)
variant.

    PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import smoke_config
from repro.data.lm import synthetic_lm_batch
from repro.models import transformer as T
from repro.train.steps import make_decode_fn, make_prefill_fn


def serve(arch: str, *, sliding: int | None = None, batch=4, prompt=48,
          gen=24):
    cfg = smoke_config(arch)
    if sliding:
        cfg = cfg.with_(sliding_window=sliding)
    window = (prompt + gen) if not sliding else sliding
    params = T.init_model(cfg, jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill_fn(cfg, window))
    decode = jax.jit(make_decode_fn(cfg))

    toks = jnp.asarray(
        synthetic_lm_batch(cfg, batch, prompt, seed=1)["tokens"])
    t0 = time.time()
    logits, cache = prefill(params, {"tokens": toks})
    nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = [nxt]
    for i in range(gen - 1):
        nxt, _, cache = decode(params, cache, nxt, jnp.int32(prompt + i))
        out.append(nxt)
    gen_toks = np.concatenate([np.asarray(t) for t in out], axis=1)
    dt = time.time() - t0
    label = f"{arch}" + (f" (sliding={sliding})" if sliding else "")
    print(f"{label:42s} prefill {prompt:3d} + decode {gen:3d} "
          f"x batch {batch}: {batch*gen/dt:7.1f} tok/s")
    # sanity: all generated ids in-vocab, deterministic greedy
    assert gen_toks.shape == (batch, gen)
    assert (gen_toks >= 0).all() and (gen_toks < cfg.vocab).all()
    return gen_toks


if __name__ == "__main__":
    serve("yi-6b")                          # dense GQA, full cache
    serve("yi-6b", sliding=16)              # rolling-buffer window
    serve("qwen3-moe-235b-a22b")            # MoE decode (top-8 routing)
    serve("zamba2-1.2b")                    # hybrid: Mamba2 state + attn
    serve("xlstm-350m")                     # pure recurrent state
    print("all serving paths OK")
